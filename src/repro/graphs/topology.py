"""Communication-graph topologies for decentralized learning.

The paper (§6.1, §6.5) evaluates Erdős–Rényi graphs of varying connectivity
``p`` plus geometric, ring and grid graphs.  We additionally provide torus,
hypercube, star and complete graphs since they are the natural shapes of TPU
interconnects (a TPU v5e pod is a 2D torus; pods connected over DCN form a
near-ring).

A :class:`Graph` is a plain frozen dataclass over an adjacency matrix so it can
be consumed by numpy / JAX / networkx alike.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected communication graph over ``num_nodes`` devices."""

    name: str
    adjacency: np.ndarray  # (K, K) symmetric 0/1, zero diagonal

    def __post_init__(self):
        adj = np.asarray(self.adjacency)
        if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
            raise ValueError(f"adjacency must be square, got {adj.shape}")
        if not np.array_equal(adj, adj.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(adj) != 0):
            raise ValueError("adjacency must have zero diagonal")
        object.__setattr__(self, "adjacency", adj.astype(np.int64))

    @property
    def num_nodes(self) -> int:
        return int(self.adjacency.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def num_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def edges(self) -> list[tuple[int, int]]:
        i, j = np.nonzero(np.triu(self.adjacency, k=1))
        return list(zip(i.tolist(), j.tolist()))

    def neighbors(self, i: int) -> list[int]:
        return np.nonzero(self.adjacency[i])[0].tolist()

    def is_connected(self) -> bool:
        k = self.num_nodes
        if k == 0:
            return False
        seen = np.zeros(k, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            u = stack.pop()
            for v in self.neighbors(u):
                if not seen[v]:
                    seen[v] = True
                    stack.append(v)
        return bool(seen.all())


def _from_edges(name: str, k: int, edges: Sequence[tuple[int, int]]) -> Graph:
    adj = np.zeros((k, k), dtype=np.int64)
    for i, j in edges:
        if i == j:
            continue
        adj[i, j] = adj[j, i] = 1
    return Graph(name=name, adjacency=adj)


def ring_graph(k: int) -> Graph:
    """Ring: node i ↔ (i±1) mod K. Paper Fig. 6(b)."""
    if k < 2:
        raise ValueError("ring needs K >= 2")
    if k == 2:
        return _from_edges("ring", k, [(0, 1)])
    return _from_edges("ring", k, [(i, (i + 1) % k) for i in range(k)])


def complete_graph(k: int) -> Graph:
    return _from_edges(
        "complete", k, [(i, j) for i in range(k) for j in range(i + 1, k)]
    )


def star_graph(k: int) -> Graph:
    """Star (PS-like) topology — kept for baselines/contrast."""
    return _from_edges("star", k, [(0, i) for i in range(1, k)])


def grid_graph(k: int, rows: int | None = None) -> Graph:
    """2D grid (non-wrapping). Paper Fig. 6(c)."""
    if rows is None:
        rows = int(math.isqrt(k))
        while k % rows:
            rows -= 1
    cols = k // rows
    if rows * cols != k:
        raise ValueError(f"cannot factor K={k} into grid {rows}x{cols}")
    edges = []
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            if c + 1 < cols:
                edges.append((u, u + 1))
            if r + 1 < rows:
                edges.append((u, u + cols))
    return _from_edges("grid", k, edges)


def torus_graph(k: int, rows: int | None = None) -> Graph:
    """2D torus — the physical ICI topology of a TPU pod slice."""
    if rows is None:
        rows = int(math.isqrt(k))
        while k % rows:
            rows -= 1
    cols = k // rows
    edges = []
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            edges.append((u, r * cols + (c + 1) % cols))
            edges.append((u, ((r + 1) % rows) * cols + c))
    return _from_edges("torus", k, edges)


def hypercube_graph(k: int) -> Graph:
    """Hypercube over K=2^m nodes: log-K degree, excellent spectral gap."""
    m = k.bit_length() - 1
    if 2**m != k:
        raise ValueError(f"hypercube needs K=2^m, got {k}")
    edges = [(i, i ^ (1 << b)) for i in range(k) for b in range(m) if i < i ^ (1 << b)]
    return _from_edges("hypercube", k, edges)


def erdos_renyi_graph(k: int, p: float, seed: int = 0, ensure_connected: bool = True) -> Graph:
    """Erdős–Rényi G(K, p), re-sampled (then ring-augmented) until connected.

    The paper's default topology (§6.1) with connectivity ratio p.
    """
    rng = np.random.default_rng(seed)
    for _ in range(200):
        mask = rng.random((k, k)) < p
        adj = np.triu(mask, 1)
        adj = (adj | adj.T).astype(np.int64)
        g = Graph("erdos_renyi", adj)
        if not ensure_connected or g.is_connected():
            return g
    # Fall back: overlay a ring so the graph is guaranteed connected.
    ring = ring_graph(k).adjacency
    return Graph("erdos_renyi", np.clip(adj + ring, 0, 1))


def geometric_graph(k: int, radius: float = 0.5, seed: int = 0) -> Graph:
    """Random geometric graph on the unit square. Paper Fig. 6(a)."""
    rng = np.random.default_rng(seed)
    for _ in range(200):
        pts = rng.random((k, 2))
        d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        adj = (d2 < radius**2).astype(np.int64)
        np.fill_diagonal(adj, 0)
        g = Graph("geometric", adj)
        if g.is_connected():
            return g
        radius = min(1.5, radius * 1.1)  # grow radius until connected
    raise RuntimeError("could not build a connected geometric graph")


_BUILDERS = {
    "ring": lambda k, **kw: ring_graph(k),
    "complete": lambda k, **kw: complete_graph(k),
    "star": lambda k, **kw: star_graph(k),
    "grid": lambda k, **kw: grid_graph(k, kw.get("rows")),
    "torus": lambda k, **kw: torus_graph(k, kw.get("rows")),
    "hypercube": lambda k, **kw: hypercube_graph(k),
    "erdos_renyi": lambda k, **kw: erdos_renyi_graph(
        k, kw.get("p", 0.3), kw.get("seed", 0)
    ),
    "geometric": lambda k, **kw: geometric_graph(
        k, kw.get("radius", 0.5), kw.get("seed", 0)
    ),
}


def build_graph(kind: str, k: int, **kwargs) -> Graph:
    """Build a graph by name; the CLI entry point for ``--graph``."""
    if kind not in _BUILDERS:
        raise ValueError(f"unknown graph kind {kind!r}; options: {sorted(_BUILDERS)}")
    return _BUILDERS[kind](k, **kwargs)
