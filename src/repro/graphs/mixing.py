"""Mixing (gossip) matrices and their decomposition into TPU collectives.

The paper (§6.1) uses Metropolis weights:

    W_ij = 1 / (1 + max(d_i, d_j))          if (i,j) ∈ E
    W_ii = 1 − Σ_{j∈N_i} W_ij
    W_ij = 0                                 otherwise

which yields a symmetric doubly-stochastic matrix with spectral norm
ρ = ||W − J|| < 1 on any connected graph (Assumption 5).

``permutation_decomposition`` rewrites a sparse W as
``W = w_self ⊙ I + Σ_c P_c ⊙ W`` where each ``P_c`` is a partial permutation
(a matching, from greedy edge coloring).  Under ``shard_map`` each matching
lowers to exactly one ``lax.ppermute`` — the native neighbor-exchange
collective of the TPU torus — so a degree-d graph costs d permutes instead of
a K-wide all-gather.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.topology import Graph


def metropolis_weights(graph: Graph) -> np.ndarray:
    """Paper §6.1 Metropolis-Hastings mixing matrix (float64)."""
    adj = graph.adjacency
    k = graph.num_nodes
    deg = graph.degrees
    w = np.zeros((k, k), dtype=np.float64)
    for i, j in graph.edges():
        w[i, j] = w[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    for i in range(k):
        w[i, i] = 1.0 - w[i].sum()
    return w


def max_degree_weights(graph: Graph) -> np.ndarray:
    """W = I − L/(Δ+1): the max-degree gossip matrix."""
    adj = graph.adjacency.astype(np.float64)
    deg = graph.degrees.astype(np.float64)
    alpha = 1.0 / (graph.max_degree + 1.0)
    w = alpha * adj
    np.fill_diagonal(w, 1.0 - alpha * deg)
    return w


def lazy_metropolis_weights(graph: Graph, laziness: float = 0.5) -> np.ndarray:
    """(1−β)·I + β·W — guarantees eigenvalues in (0, 1], useful for analysis."""
    if not 0.0 < laziness <= 1.0:
        raise ValueError("laziness must be in (0, 1]")
    w = metropolis_weights(graph)
    return (1.0 - laziness) * np.eye(graph.num_nodes) + laziness * w


def metropolis_weights_traced(adj):
    """Traced (jnp) twin of :func:`metropolis_weights` for dynamic graphs.

    ``adj`` is a (K, K) symmetric 0/1 adjacency that may be a *traced* jax
    array (per-round re-draws, link dropout), so a time-varying topology can
    re-derive its Metropolis weights on device every round without
    recompiling.  Zero-degree (isolated) nodes degenerate to W_ii = 1.
    """
    k = adj.shape[0]
    eye = jnp.eye(k, dtype=jnp.float32)
    a = adj.astype(jnp.float32) * (1.0 - eye)
    deg = a.sum(axis=1)
    w = a / (1.0 + jnp.maximum(deg[:, None], deg[None, :]))
    return w + jnp.diag(1.0 - w.sum(axis=1))


def renormalize_masked_weights(w, keep):
    """Mask links out of a doubly-stochastic W, returning mass to the diagonal.

    ``w`` is a (K, K) doubly-stochastic matrix and ``keep`` a symmetric
    (K, K) 0/1 link mask (diagonal ignored); both may be traced.  Every
    dropped link's weight moves to the *two* incident diagonals:

        W'_ij = W_ij · keep_ij                     (i ≠ j)
        W'_ii = W_ii + Σ_j W_ij · (1 − keep_ij)

    which preserves symmetry and (exact) row sums, so W' stays doubly
    stochastic — the on-device Metropolis renormalization of the dynamics
    subsystem.  With ``keep ≡ 1`` the result is bit-identical to ``w``.
    """
    k = w.shape[0]
    eye = jnp.eye(k, dtype=jnp.float32)
    off = w * (1.0 - eye)
    kept = off * keep.astype(jnp.float32)
    returned = (off - kept).sum(axis=1)
    return kept + jnp.diag(jnp.diagonal(w) + returned)


def symmetric_uniform(key, k: int):
    """Symmetric (K, K) U[0,1) matrix: one shared draw per unordered pair.

    Both consensus lowerings (dense einsum and gossip matchings) read link
    coins from this one matrix, so dropout decisions agree bit-for-bit
    across lowerings at a fixed seed.
    """
    u = jax.random.uniform(key, (k, k), jnp.float32)
    upper = jnp.triu(u, 1)
    return upper + upper.T


def is_doubly_stochastic(w: np.ndarray, atol: float = 1e-9) -> bool:
    w = np.asarray(w)
    ones = np.ones(w.shape[0])
    return (
        bool(np.allclose(w, w.T, atol=atol))
        and bool(np.allclose(w @ ones, ones, atol=atol))
        and bool((w >= -atol).all())
    )


def spectral_norm(w: np.ndarray) -> float:
    """ρ = ||WᵀW − J||₂ (Assumption 5). Convergence requires ρ < 1."""
    k = w.shape[0]
    j = np.full((k, k), 1.0 / k)
    return float(np.linalg.norm(w.T @ w - j, ord=2))


def spectral_gap(w: np.ndarray) -> float:
    """1 − ρ: larger gap ⇒ faster consensus (third term of Theorem 1)."""
    return 1.0 - spectral_norm(w)


@dataclasses.dataclass(frozen=True)
class MixingDecomposition:
    """W as self-weights + permutation (matching) classes.

    Attributes:
      self_weights: (K,) diagonal of W.
      matchings: list of matchings; each is a (K,) int array ``perm`` where
        ``perm[i] = j`` if i exchanges with j in this round and ``perm[i] = i``
        if i idles. Matchings are involutions (perm[perm[i]] == i).
      matching_weights: list of (K,) arrays; entry i is W[i, perm[i]]
        (0 where idle).
    """

    self_weights: np.ndarray
    matchings: list[np.ndarray]
    matching_weights: list[np.ndarray]

    @property
    def num_rounds(self) -> int:
        return len(self.matchings)

    def ppermute_pairs(self) -> list[list[tuple[int, int]]]:
        """Per-matching ``lax.ppermute`` (src, dst) pairs.

        Node i receives from j = perm[i] -> pair (j, i); idle nodes (fixed
        points of the matching) are omitted, so ppermute zero-fills them.
        The single source of truth for gossip edge routing — both the plain
        and the compressed gossip mixers consume this.
        """
        k = self.self_weights.shape[0]
        return [
            [(int(p[i]), i) for i in range(k) if int(p[i]) != i]
            for p in self.matchings
        ]

    def reconstruct(self) -> np.ndarray:
        """Rebuild the dense W (for testing exactness)."""
        k = self.self_weights.shape[0]
        w = np.diag(self.self_weights).astype(np.float64)
        for perm, pw in zip(self.matchings, self.matching_weights):
            for i in range(k):
                j = int(perm[i])
                if j != i:
                    w[i, j] += pw[i]
        return w


def _misra_gries_coloring(k: int, edges: list[tuple[int, int]]
                          ) -> tuple[dict[tuple[int, int], int], int]:
    """Misra & Gries (1992) proper edge coloring with at most Δ+1 colors.

    Guarantees the gossip consensus needs at most Δ+1 collective-permute
    rounds per mixing step (greedy can need up to 2Δ−1 on adversarial
    orders). O(E·Δ) — fine for the K ≤ a-few-hundred node graphs here.
    """
    deg = np.zeros(k, dtype=np.int64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    n_colors = int(deg.max()) + 1 if len(edges) else 1
    # color[u][c] = neighbor matched to u with color c (or -1)
    color_at = np.full((k, n_colors), -1, dtype=np.int64)
    edge_color: dict[tuple[int, int], int] = {}

    def free_colors(u):
        return [c for c in range(n_colors) if color_at[u, c] == -1]

    def set_color(u, v, c):
        color_at[u, c] = v
        color_at[v, c] = u
        edge_color[(min(u, v), max(u, v))] = c

    def unset_color(u, v, c):
        color_at[u, c] = -1
        color_at[v, c] = -1
        edge_color.pop((min(u, v), max(u, v)), None)

    for (x, y) in edges:
        # build maximal fan of x starting at y
        fan = [y]
        fan_set = {y}
        while True:
            extended = False
            last = fan[-1]
            free_last = set(free_colors(last))
            for c in free_last:
                z = color_at[x, c]
                if z != -1 and z not in fan_set:
                    fan.append(z)
                    fan_set.add(z)
                    extended = True
                    break
            if not extended:
                break
        c = free_colors(x)[0]
        d = free_colors(fan[-1])[0]
        if c != d:
            # invert the cd_x path from x
            u, col = x, d
            path = []
            while True:
                v = color_at[u, col]
                if v == -1:
                    break
                path.append((u, v, col))
                u, col = v, (c if col == d else d)
            for (u, v, col) in path:
                unset_color(u, v, col)
            for (u, v, col) in path:
                set_color(u, v, c if col == d else d)
        # rotate the fan up to the first vertex where d is free
        w_idx = len(fan) - 1
        for idx, f in enumerate(fan):
            if color_at[f, d] == -1:
                w_idx = idx
                break
        for idx in range(w_idx):
            nxt = fan[idx + 1]
            col = edge_color[(min(x, nxt), max(x, nxt))]
            unset_color(x, nxt, col)
            set_color(x, fan[idx], col)
        set_color(x, fan[w_idx], d)

    used = sorted({c for c in edge_color.values()})
    remap = {c: i for i, c in enumerate(used)}
    return {e: remap[c] for e, c in edge_color.items()}, len(used)


def _greedy_coloring(k: int, edges: list[tuple[int, int]]
                     ) -> tuple[dict[tuple[int, int], int], int]:
    """Greedy edge coloring (≤ 2Δ−1 worst case, often optimal on regular
    graphs — e.g. exactly 2 colors on even rings where Misra-Gries may use
    Δ+1 = 3)."""
    deg = np.zeros(k, dtype=np.int64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    order = sorted(edges, key=lambda e: -(deg[e[0]] + deg[e[1]]))
    used: list[set[int]] = [set() for _ in range(k)]
    edge_color: dict[tuple[int, int], int] = {}
    n_colors = 0
    for i, j in order:
        c = 0
        while c in used[i] or c in used[j]:
            c += 1
        edge_color[(i, j)] = c
        used[i].add(c)
        used[j].add(c)
        n_colors = max(n_colors, c + 1)
    return edge_color, n_colors


def permutation_decomposition(w: np.ndarray, atol: float = 1e-12) -> MixingDecomposition:
    """Edge coloring of supp(W) into matchings: best of greedy and
    Misra-Gries, so the result is always ≤ Δ+1 classes (MG guarantee) and
    optimal on the common regular topologies (greedy).

    Each matching becomes one ``lax.ppermute`` in the gossip consensus op.
    """
    w = np.asarray(w, dtype=np.float64)
    k = w.shape[0]
    if not np.allclose(w, w.T, atol=1e-9):
        raise ValueError("mixing matrix must be symmetric")
    edges = [
        (i, j)
        for i in range(k)
        for j in range(i + 1, k)
        if abs(w[i, j]) > atol
    ]
    ec_g, n_g = _greedy_coloring(k, edges)
    ec_mg, n_mg = _misra_gries_coloring(k, edges)
    edge_color, n_colors = (ec_g, n_g) if n_g <= n_mg else (ec_mg, n_mg)
    matchings, matching_weights = [], []
    for c in range(n_colors):
        perm = np.arange(k)
        pw = np.zeros(k, dtype=np.float64)
        for (i, j), col in edge_color.items():
            if col == c:
                perm[i], perm[j] = j, i
                pw[i] = w[i, j]
                pw[j] = w[j, i]
        matchings.append(perm)
        matching_weights.append(pw)
    return MixingDecomposition(
        self_weights=np.diag(w).copy(),
        matchings=matchings,
        matching_weights=matching_weights,
    )
