import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

For each combination this produces, WITHOUT allocating any model memory:
  * proof that the distribution config lowers and compiles (the deliverable),
  * ``memory_analysis()``  — per-device argument/output/temp bytes,
  * ``cost_analysis()``    — HLO FLOPs / bytes accessed,
  * collective wire bytes  — parsed from the compiled HLO text,
  * scan-trip-count-corrected totals: XLA's cost analysis counts a `while`
    body once, so two *unrolled* probe lowers with 1 and 2 pattern groups fit
    cost(G) = a + b*G, extrapolated to the real group count.

Shapes: train_4k lowers the decentralized DR-DSGD train_step (node axis =
"data" / ("pod","data")); prefill_32k lowers `prefill`; decode shapes lower
`serve_step` (one token against the KV/recurrent cache). `long_500k` runs
only for sub-quadratic archs (ssm / hybrid / SWA-only) per the task spec.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --mixer dense --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.dynamics import TOPOLOGY_KINDS
from repro.core import (
    CompressionConfig, RobustConfig, TrainStepConfig,
    add_compression_cli_args, build_train_step, compression_from_args,
    make_dense_mixer, make_gossip_mixer,
)
from repro.core.drdsgd import DecentralizedState
from repro.graphs import (
    build_graph, metropolis_weights, permutation_decomposition,
)
from repro.launch.mesh import make_production_mesh, node_axes, num_nodes
from repro.models import SHAPES, TransformerLM, input_shapes
from repro.obs import expect_compiles
from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import sgd
from repro.utils.compat import make_auto_mesh
from repro.utils.hlo import collective_summary, parse_collectives
from repro.utils.roofline import model_flops


def runs_shape(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.arch_type in ("ssm", "hybrid") or cfg.is_subquadratic
    return True


def _node_stack_shapes(tree, k: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((k,) + s.shape, s.dtype), tree)


def _shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# -- builders per execution mode ---------------------------------------------

def build_train(cfg: ArchConfig, shape: ShapeConfig, mesh, mixer_kind: str,
                graph_kind: str = "ring",
                compression: CompressionConfig | None = None,
                topology: str = "dropout", drop_p: float = 0.2,
                ef_rebase_every: int = 8):
    """Returns (fn, example_args, in_shardings)."""
    model = TransformerLM(cfg)
    hier = "fsdp" in mesh.axis_names
    k = num_nodes(mesh)
    naxes = node_axes(mesh)
    node_axis = naxes[0] if len(naxes) == 1 else tuple(naxes)
    g = build_graph(graph_kind, k)
    w = metropolis_weights(g)
    pspecs = model.param_specs(
        mesh, mode="train_fsdp" if hier else "train", node_axis=node_axis)
    if mixer_kind == "dense":
        mixer = make_dense_mixer(w, compression=compression)
    elif mixer_kind == "gossip":
        mixer = make_gossip_mixer(
            permutation_decomposition(w), mesh, node_axis, pspecs,
            compression=compression)
    elif mixer_kind == "gossip-dynamic":
        # time-varying topology lowering (repro.dynamics): static ppermute
        # structure over the union support, traced per-round weights/masks.
        # An error-feedback config builds the EF wire with periodic hat_mix
        # re-basing (DynamicCompressedGossipMixer, --ef-rebase-every);
        # --no-error-feedback keeps the memoryless masked int8 kernel wire.
        from repro.dynamics import DynamicGossipMixer, make_schedule

        if (compression is not None and compression.enabled
                and not compression.error_feedback
                and compression.kind not in ("int8", "int4")):
            raise ValueError(
                "the memoryless gossip-dynamic wire serves --compress "
                "int8/int4 (masked kernel wire, traced qmax); "
                "error-feedback configs take any codec")
        mixer = DynamicGossipMixer(
            make_schedule(topology, w=w, k=k, drop_p=drop_p),
            mesh, node_axis, pspecs, quantized=compression,
            ef_rebase_every=ef_rebase_every)
    else:
        raise ValueError(mixer_kind)
    step_cfg = TrainStepConfig(
        robust=RobustConfig(mu=6.0), metrics_disagreement=False,
        compression=compression)
    train_step = build_train_step(model.loss, sgd(1e-2), mixer, step_cfg)

    params = _node_stack_shapes(model.param_shapes(), k)
    # uniform Mixer protocol: every mixer allocates (and shards) a CommState
    comm = jax.eval_shape(mixer.init_state, params)
    state = DecentralizedState(
        params=params, opt_state=(), step=jax.ShapeDtypeStruct((), jnp.int32),
        comm=comm)
    batch = input_shapes(cfg, shape, num_nodes=k)

    comm_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), mixer.state_specs(pspecs),
        is_leaf=lambda x: isinstance(x, P))
    state_sh = DecentralizedState(
        params=_shardings(mesh, pspecs),
        opt_state=(),
        step=NamedSharding(mesh, P()),
        comm=comm_sh,
    )
    # hierarchical mode: the per-node batch dim is FSDP data-parallel
    inner = "fsdp" if hier else None
    batch_sh = jax.tree.map(
        lambda s: NamedSharding(
            mesh, P(node_axis, inner, *([None] * (len(s.shape) - 2)))),
        batch)
    fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, None))
    return fn, (state, batch)


def build_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh):
    model = TransformerLM(cfg)
    daxes = node_axes(mesh)
    dax = daxes[0] if len(daxes) == 1 else tuple(daxes)
    pspecs = model.param_specs(mesh, mode="serve")
    batch = input_shapes(cfg, shape)
    batch_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P(dax, *([None] * (len(s.shape) - 1)))),
        batch)
    fn = jax.jit(model.prefill,
                 in_shardings=(_shardings(mesh, pspecs), batch_sh))
    return fn, (model.param_shapes(), batch)


def build_decode(cfg: ArchConfig, shape: ShapeConfig, mesh):
    model = TransformerLM(cfg)
    daxes = node_axes(mesh)
    dax = daxes[0] if len(daxes) == 1 else tuple(daxes)
    b, s = shape.global_batch, shape.seq_len
    pspecs = model.param_specs(mesh, mode="serve")
    cache_shapes = jax.eval_shape(lambda: model.init_cache(b, s))
    cache_specs = model.cache_pspecs(b, s, mesh, dax)
    inputs = input_shapes(cfg, shape)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    tok_spec = P(dax, None) if b % dsize == 0 else P(None, None)
    in_sh = (
        _shardings(mesh, pspecs),
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, P()),
        _shardings(mesh, cache_specs),
    )
    fn = jax.jit(model.decode_step, in_shardings=in_sh, donate_argnums=(3,))
    args = (model.param_shapes(), inputs["token"], inputs["pos"], cache_shapes)
    return fn, args


def build_fn(cfg, shape, mesh, mixer_kind, graph_kind="ring",
             compression=None, topology="dropout", drop_p=0.2,
             ef_rebase_every=8):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, mixer_kind, graph_kind,
                           compression, topology=topology, drop_p=drop_p,
                           ef_rebase_every=ef_rebase_every)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)


# -- compile + measure ---------------------------------------------------------

def _cost_entries(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def compile_and_measure(cfg, shape, mesh, mixer_kind, want_hlo=True,
                        graph_kind="ring", compression=None,
                        topology="dropout", drop_p=0.2, ef_rebase_every=8,
                        audit=False):
    fn, args = build_fn(cfg, shape, mesh, mixer_kind, graph_kind, compression,
                        topology=topology, drop_p=drop_p,
                        ef_rebase_every=ef_rebase_every)
    t0 = time.time()
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    out = {
        "lower_s": t_lower,
        "compile_s": t_compile,
        "cost": _cost_entries(compiled),
    }
    ma = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    if want_hlo:
        txt = compiled.as_text()
        colls = parse_collectives(txt, world_size=mesh.devices.size)
        out["collectives"] = collective_summary(colls)
    if audit:
        # static-analysis pass over the program that just compiled: stray
        # host callbacks (anything outside repro.obs) and scalar baked
        # constants (recompile hazards) — repro.analysis.audit
        from repro.analysis.audit import (
            audit_baked_consts, audit_host_callbacks,
        )

        closed = jax.make_jaxpr(fn)(*args)
        findings = (audit_host_callbacks(closed)
                    + audit_baked_consts(closed))
        out["audit"] = [str(f) for f in findings]
        errors = [f for f in findings if f.severity == "error"]
        if errors:
            raise RuntimeError(
                "audit errors in compiled program: "
                + "; ".join(str(f) for f in errors))
    return out


def _with_groups(cfg: ArchConfig, g: int, keep_chunking: bool = False
                 ) -> ArchConfig:
    """Probe variant: g pattern groups, fully unrolled AND unchunked.

    Unrolled: `lax.scan` bodies are counted once by XLA's cost analysis, so
    trip counts must not hide in while-loops.  Unchunked: the chunked
    attention / CE paths scan over blocks for memory reasons; probes raise
    the chunk sizes so each becomes a single (counted) block.  The remaining
    inner recurrences (mamba/rwkv time scans) stay undercounted but their
    FLOPs are negligible vs the projections (see EXPERIMENTS.md §Roofline
    conventions).  Consequence: probe "bytes" include the S^2 attention
    score traffic a fused flash kernel avoids — the memory term is an upper
    bound for attention-heavy shapes (quantified in §Perf).
    """
    big = 1 << 30
    n_layers = cfg.first_k_dense + cfg.pattern_len * g
    if keep_chunking:
        return dataclasses.replace(cfg, n_layers=n_layers, scan_layers=False)
    return dataclasses.replace(
        cfg, n_layers=n_layers, scan_layers=False,
        attn_q_chunk=big, attn_kv_chunk=big, logits_chunk=big)


def fit_scan_correction(cfg, shape, mesh, mixer_kind, graph_kind="ring",
                        compression=None, keep_chunking=False,
                        topology="dropout", drop_p=0.2, ef_rebase_every=8):
    """Unrolled G=1 / G=2 probes -> cost(G) = a + b*G, evaluated at n_groups."""
    probes = {}
    for g in (1, 2):
        r = compile_and_measure(
            _with_groups(cfg, g, keep_chunking=keep_chunking), shape, mesh,
            mixer_kind, graph_kind=graph_kind, compression=compression,
            topology=topology, drop_p=drop_p, ef_rebase_every=ef_rebase_every)
        probes[g] = {
            "flops": r["cost"]["flops"],
            "bytes": r["cost"]["bytes"],
            "wire_bytes": r["collectives"]["total_wire_bytes"],
        }
    n = cfg.n_groups
    fitted = {}
    for key in ("flops", "bytes", "wire_bytes"):
        b = probes[2][key] - probes[1][key]
        a = probes[1][key] - b
        fitted[key] = a + b * n
        fitted[f"{key}_per_group"] = b
        fitted[f"{key}_head"] = a
    fitted["probes"] = probes
    return fitted


def run_one(arch: str, shape_name: str, multi_pod: bool, mixer_kind: str,
            out_dir: str, skip_existing: bool = True, graph_kind: str = "ring",
            compression=None, compute_dtype=None, moe_constraints: bool = False,
            keep_chunking: bool = False, variant: str = "",
            hier_nodes: int = 0, remat_policy: str = "",
            topology: str = "dropout", drop_p: float = 0.2,
            ef_rebase_every: int = 8, audit: bool = False) -> dict | None:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    label = mixer_kind + (f"+{compression.kind}" if compression else "") \
        + (f"+sched-{compression.schedule.kind}"
           if compression and compression.schedule else "") \
        + (f"+{variant}" if variant else "")
    tag = f"{arch}__{shape_name}__{mesh_name}__{label}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[skip] {tag} (exists)")
        with open(path) as f:
            return json.load(f)
    if not runs_shape(cfg, shape):
        print(f"[skip] {tag}: long_500k needs sub-quadratic attention "
              f"({cfg.name} is full-attention; see DESIGN.md)")
        return None

    if hier_nodes:
        total = 512 if multi_pod else 256
        fsdp = total // (hier_nodes * 16)
        mesh = make_auto_mesh(
            (hier_nodes, fsdp, 16), ("data", "fsdp", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    if compute_dtype is not None:
        cfg = dataclasses.replace(cfg, compute_dtype=compute_dtype)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if moe_constraints and cfg.moe is not None:
        daxes = node_axes(mesh)
        dax = daxes[0] if len(daxes) == 1 else tuple(daxes)
        if moe_constraints == "capacity":
            espec = P(None, dax, None)       # shard expert capacity dim
        else:
            ok = cfg.moe.num_experts % int(
                np.prod([mesh.shape[a] for a in daxes])) == 0
            espec = P(dax if ok else None, None, None)  # expert parallelism
        cfg = dataclasses.replace(
            cfg, moe_dispatch_specs=(
                NamedSharding(mesh, P(dax, None)),
                NamedSharding(mesh, espec)))
    model = TransformerLM(cfg)
    print(f"[run ] {tag}: {model.num_params()/1e9:.2f}B params ...", flush=True)
    # recompile watchdog on the AOT path (no jit cache to snapshot —
    # lower().compile() never populates one): one combination performs
    # exactly 3 genuine backend compiles (the full program + the two
    # unrolled G=1/G=2 probes).  The budget carries slack because the
    # monitoring counter also sees first-touch eager-op compiles and
    # per-compile event fan-out; a traced operand leaking into program
    # structure shows up as O(n_groups) extra compiles, far past 16.
    with expect_compiles(at_most=16, label=tag):
        res = compile_and_measure(cfg, shape, mesh, mixer_kind,
                                  graph_kind=graph_kind,
                                  compression=compression,
                                  topology=topology, drop_p=drop_p,
                                  ef_rebase_every=ef_rebase_every,
                                  audit=audit)
        fitted = fit_scan_correction(cfg, shape, mesh, mixer_kind,
                                     graph_kind=graph_kind,
                                     compression=compression,
                                     keep_chunking=keep_chunking,
                                     topology=topology, drop_p=drop_p,
                                     ef_rebase_every=ef_rebase_every)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = model_flops(model.num_params(), tokens,
                     "train" if shape.kind == "train" else "serve",
                     active_params=model.num_active_params())
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mixer": label,
        "graph": graph_kind,
        "variant": variant,
        "chips": int(mesh.devices.size),
        "num_nodes": num_nodes(mesh) if shape.kind == "train" else None,
        "params": model.num_params(),
        "active_params": model.num_active_params(),
        "tokens": tokens,
        "model_flops": mf,
        "n_groups": cfg.n_groups,
        "full": res,
        "fitted": fitted,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    mem = res["memory"]
    print(f"       compile={res['compile_s']:.1f}s "
          f"arg={mem['argument_bytes']/1e9:.2f}GB temp={mem['temp_bytes']/1e9:.2f}GB "
          f"flops_fit={fitted['flops']:.3e} wire_fit={fitted['wire_bytes']:.3e}",
          flush=True)
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mixer", default="dense",
                    choices=["dense", "gossip", "gossip-dynamic"])
    # geometric is excluded: its support moves every round, so only the
    # dense lowering can run it; hub is excluded: the star consensus has no
    # per-round schedule, it lowers through the dense path (make_hub_mixer)
    ap.add_argument("--topology", default="dropout",
                    choices=[k for k in TOPOLOGY_KINDS
                             if k not in ("geometric", "hub")],
                    help="gossip-dynamic: per-round topology schedule")
    ap.add_argument("--drop-p", type=float, default=0.2,
                    help="gossip-dynamic: link dropout probability")
    ap.add_argument("--ef-rebase-every", type=int, default=8,
                    help="gossip-dynamic: hat_mix re-base period B of the "
                         "error-feedback compressed wire (0 = never; "
                         "static schedules only)")
    ap.add_argument("--graph", default="ring")
    add_compression_cli_args(ap)
    ap.add_argument("--compute-dtype", default=None, choices=[None, "bf16"])
    ap.add_argument("--moe-constraints", default=None,
                    choices=[None, "expert", "capacity"])
    ap.add_argument("--keep-chunking", action="store_true",
                    help="probe with the chunked attention/CE paths (memory-"
                         "realistic bytes; see §Perf)")
    ap.add_argument("--variant", default="",
                    help="label suffix for the output file")
    ap.add_argument("--hier-nodes", type=int, default=0,
                    help="hierarchical mode: K nodes x (chips/16K) FSDP x 16 TP")
    ap.add_argument("--remat-policy", default="", choices=["", "full", "dots"])
    ap.add_argument("--audit", action="store_true",
                    help="run the repro.analysis.audit static passes (host "
                         "callbacks, baked scalar consts) over each compiled "
                         "combination; errors fail the combination")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    compression = compression_from_args(args)
    comp = jnp.bfloat16 if args.compute_dtype == "bf16" else None

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    run_one(arch, shape, multi, args.mixer, args.out,
                            skip_existing=not args.force,
                            graph_kind=args.graph, compression=compression,
                            compute_dtype=comp,
                            moe_constraints=args.moe_constraints,
                            keep_chunking=args.keep_chunking,
                            variant=args.variant,
                            hier_nodes=args.hier_nodes,
                            remat_policy=args.remat_policy,
                            topology=args.topology, drop_p=args.drop_p,
                            ef_rebase_every=args.ef_rebase_every,
                            audit=args.audit)
                except Exception as e:  # a failure here is a sharding bug
                    failures.append((arch, shape, multi, repr(e)))
                    print(f"[FAIL] {arch} {shape} multi={multi}: {e!r}",
                          flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run combinations lowered and compiled successfully.")


if __name__ == "__main__":
    main()
