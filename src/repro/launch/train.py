"""Decentralized (DR-)DSGD training driver.

Runs the paper's algorithm end-to-end on any of the assigned architectures
(synthetic token streams, per-node distribution shift) or the paper's own
MLP/CNN image models.  On this CPU container use the smoke configs; on a real
TPU slice the same entry point takes ``--mesh single|multi`` and shards the
node axis across the pod(s).

Trainer construction is declarative (``repro.core.TrainerSpec``: the same
flags drive the benchmarks and examples) and the hot loop runs through
``DecentralizedTrainer.run`` — one compiled ``lax.scan`` program per logging
segment with the carried state donated, instead of a per-step Python
dispatch loop.

Telemetry (``repro.obs``): every run streams through a
:class:`~repro.obs.MetricsSink` — the in-graph tap payload delivers
one ``train`` record per optimizer step (scalar metrics + per-node losses
and DR weights), the eval hook writes the paper's fairness metrics as
``eval`` records, and ``run_segments`` rolls up wall-clock phase timings as
``perf`` records.  The console lines below are *formatters over those same
records*; ``--log-dir`` additionally persists them as schema-versioned
JSONL (``python -m repro.obs.schema`` validates; ``python -m repro.obs
report <log-dir>`` renders the fairness/comm summary and derives the
per-round fault / EF re-base / rate-switch trace events), and ``--profile``
wraps the run in ``jax.profiler.trace`` (phases carry ``obs:...`` scopes).
Per-node vectors and in-jit histogram counts ride the tap decimated
(``--tap-vectors-every``); scalars land every step.

Dynamic graphs (``repro.dynamics``): ``--topology dropout --drop-p 0.3``
trains over per-round Bernoulli link failures (renormalized on device, one
compiled program for the whole run); ``--local-updates H`` runs H local
steps per consensus round, ``--gradient-tracking`` adds the drift
correction, and ``--straggler-p/--outage-p`` inject node faults.

Consensus wire compression (``repro.comm``): ``--compress`` selects the
codec (bf16 cast, int8/int4 stochastic-rounding quantization, topk/randk
sparsification with ``--compress-ratio``), all with error-feedback
innovation gossip so convergence tracks the uncompressed mixer while the
per-round ``comm_bytes`` metric drops 2-50x.

Sanitizer (``repro.analysis``): ``--sanitize`` checkify-wraps the compiled
step with runtime invariant checks — doubly-stochastic W each round, CHOCO
error-feedback cache drift, finite post-dequant parameters, in-container
codec rate.  A violation raises host-side at the next segment boundary; the
trajectory is bit-exact with the flag off (see EXPERIMENTS.md
§Static-analysis for the measured overhead).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --steps 20 --nodes 4 --batch-per-node 2 --seq-len 64
  PYTHONPATH=src python -m repro.launch.train --paper fmnist --steps 150
  PYTHONPATH=src python -m repro.launch.train --paper fmnist --steps 150 \
      --log-dir runs/fmnist --profile
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --steps 20 --nodes 4 --compress topk --compress-ratio 0.05
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import save_train_state
from repro.configs import get_arch, fmnist_default, cifar_default
from repro.core import TrainerSpec, add_obs_cli_args, run_segments
from repro.data import (
    make_cifar_like,
    make_fmnist_like,
    make_node_token_streams,
    pathological_noniid_partition,
)
from repro.models import TransformerLM, mlp_init, mlp_apply, cnn_init, cnn_apply
from repro.models.paper_nets import make_classifier_loss
from repro.obs import (
    MetricsSink,
    format_eval,
    format_meta,
    format_train,
    profile,
)


def _dynamics_meta(spec: TrainerSpec) -> dict:
    """Fault/EF config fields of the meta record — what
    ``python -m repro.obs report`` needs to replay the run's fault events
    host-side (repro.obs.trace) without any device logging."""
    return dict(
        seed=spec.seed, drop_p=spec.drop_p, straggler_p=spec.straggler_p,
        outage_p=spec.outage_p, outage_len=spec.outage_len,
        ef_rebase_every=spec.ef_rebase_every,
        ef_rebase_threshold=spec.ef_rebase_threshold)


def train_lm(args, sink: MetricsSink):
    args.steps = args.steps or 50
    args.batch_per_node = args.batch_per_node or 2
    cfg = get_arch(args.arch, smoke=args.smoke)
    model = TransformerLM(cfg)
    spec = TrainerSpec.from_args(args, num_nodes=8, lr=0.01, grad_clip=1.0,
                                 graph="ring")
    k = spec.num_nodes
    seq = args.seq_len

    trainer = spec.build(model.loss, obs=sink)
    print(format_meta(sink.log(
        "meta", 0, arch=cfg.name, params=model.num_params(), nodes=k,
        rho=round(trainer.rho, 4), mu=args.mu, robust=spec.robust,
        compress=args.compress, topology=spec.topology,
        local_updates=spec.local_updates, steps=args.steps,
        sanitize=spec.sanitize, **_dynamics_meta(spec))))
    state = trainer.init(model.init(jax.random.PRNGKey(args.seed)))
    streams = make_node_token_streams(k, cfg.vocab, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prefix = cfg.frontend_len if cfg.frontend != "token" else 0

    def sample_batch(step):
        toks = np.stack([
            s.next_batch(args.batch_per_node, seq) for s in streams])
        batch = {"tokens": toks}
        if prefix:
            batch["embeddings"] = rng.standard_normal(
                (k, args.batch_per_node, prefix, cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch

    history = []
    t0 = time.time()
    compressed = trainer.compression is not None

    def on_segment(step, seg_state, ms):
        # the console line and the history entry are the SAME record the
        # in-graph tap delivered for this step — no parallel metrics path
        rec = sink.last("train")
        rec = dict(rec) if rec is not None else {"step": step}
        rec["wall_s"] = time.time() - t0
        history.append(rec)
        print(format_train(rec, compressed=compressed))

    with profile(args.log_dir, enabled=args.profile) as prof:
        state = run_segments(trainer, state, sample_batch, args.steps,
                             args.log_every, on_segment, obs=sink)
        sink.barrier()
    if prof.trace_path:
        print(f"profiler trace: {prof.trace_path}")
    if args.ckpt_dir:
        # full DecentralizedState incl. CommState (EF residuals, schedule
        # norms, dynamics tracking) — restore_train_state resumes bit-exactly
        save_train_state(args.ckpt_dir, args.steps, state)
        print(f"checkpoint saved to {args.ckpt_dir}")
    return history


def train_paper(args, sink: MetricsSink):
    exp = fmnist_default() if args.paper == "fmnist" else cifar_default()
    steps = args.steps or exp.steps
    if args.paper == "fmnist":
        ds = make_fmnist_like()
        params = mlp_init(jax.random.PRNGKey(args.seed))
        apply_fn = mlp_apply
    else:
        ds = make_cifar_like()
        params = cnn_init(jax.random.PRNGKey(args.seed))
        apply_fn = cnn_apply
    spec = TrainerSpec.from_args(
        args, num_nodes=exp.num_nodes, lr=exp.lr,
        graph="erdos_renyi", graph_kwargs={"p": exp.p, "seed": args.seed})
    k = spec.num_nodes
    fed = pathological_noniid_partition(ds, k, seed=args.seed)
    x_nodes, y_nodes = fed.per_node_test_sets(n_per_node=200, seed=args.seed)
    trainer = spec.build(make_classifier_loss(apply_fn), apply_fn, obs=sink)
    state = trainer.init(params)
    rng = np.random.default_rng(args.seed)
    bsz = args.batch_per_node or exp.batch_size
    print(format_meta(sink.log(
        "meta", 0, paper=args.paper, nodes=k, steps=steps, batch=bsz,
        lr=spec.lr, mu=args.mu, rho=round(trainer.rho, 4),
        compress=args.compress, topology=spec.topology,
        local_updates=spec.local_updates, sanitize=spec.sanitize,
        **_dynamics_meta(spec))))

    def sample_batch(step):
        xb, yb = fed.sample_batch(rng, bsz)
        return (xb, yb)

    def on_segment(step, seg_state, ms):
        # paper fairness metrics (worst-distribution accuracy, per-device
        # STDEV) into the telemetry stream, with the DR-weight snapshot of
        # the last train step riding along
        stats = trainer.eval_local_distributions(seg_state, x_nodes, y_nodes)
        # dr_weights is decimated (vector_every): take the newest record
        # that actually carries it, not the newest record
        train_rec = sink.last_with("train", "dr_weights")
        rec = sink.log(
            "eval", step,
            loss_mean=float(ms["loss_mean"][-1]),
            comm_bytes=float(ms["comm_bytes"][-1]),
            dr_weights=(train_rec or {}).get("dr_weights"),
            **stats)
        print(format_eval(rec))

    with profile(args.log_dir, enabled=args.profile) as prof:
        state = run_segments(trainer, state, sample_batch, steps,
                             args.log_every, on_segment, obs=sink)
        sink.barrier()
    if prof.trace_path:
        print(f"profiler trace: {prof.trace_path}")
    return state


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--paper", default=None, choices=["fmnist", "cifar"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch-per-node", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    add_obs_cli_args(ap)
    TrainerSpec.add_cli_args(ap)
    args = ap.parse_args()
    with MetricsSink(args.log_dir,
                     vector_every=args.tap_vectors_every) as sink:
        if args.paper:
            train_paper(args, sink)
        elif args.arch:
            train_lm(args, sink)
        else:
            raise SystemExit("provide --arch <id> or --paper fmnist|cifar")
        if sink.path:
            print(f"telemetry: {sink.path}")


if __name__ == "__main__":
    main()
