"""Decentralized (DR-)DSGD training driver.

Runs the paper's algorithm end-to-end on any of the assigned architectures
(synthetic token streams, per-node distribution shift) or the paper's own
MLP/CNN image models.  On this CPU container use the smoke configs; on a real
TPU slice the same entry point takes ``--mesh single|multi`` and shards the
node axis across the pod(s).

Consensus wire compression (``repro.comm``): ``--compress`` selects the
codec (bf16 cast, int8/int4 stochastic-rounding quantization, topk/randk
sparsification with ``--compress-ratio``), all with error-feedback
innovation gossip so convergence tracks the uncompressed mixer while the
per-round ``comm_bytes`` metric drops 2-50x.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --steps 20 --nodes 4 --batch-per-node 2 --seq-len 64
  PYTHONPATH=src python -m repro.launch.train --paper fmnist --steps 150
  PYTHONPATH=src python -m repro.launch.train --paper fmnist --steps 150 \
      --compress int8
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --steps 20 --nodes 4 --compress topk --compress-ratio 0.05
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_arch, fmnist_default, cifar_default
from repro.core import (
    CompressionConfig, DecentralizedTrainer, RobustConfig, ScheduleConfig,
)
from repro.data import (
    make_cifar_like,
    make_fmnist_like,
    make_node_token_streams,
    pathological_noniid_partition,
)
from repro.models import TransformerLM, mlp_init, mlp_apply, cnn_init, cnn_apply
from repro.models.paper_nets import make_classifier_loss
from repro.optim import sgd


def _compression_from_args(args) -> CompressionConfig | None:
    if args.compress == "none":
        if args.compress_schedule != "none":
            raise SystemExit(
                "--compress-schedule needs a codec: pass --compress "
                "int8|int4|topk|randk")
        return None
    schedule = None
    if args.compress_schedule != "none":
        schedule = ScheduleConfig(
            kind=args.compress_schedule,
            threshold=args.schedule_threshold,
            warmup_rounds=args.schedule_warmup,
            anneal_rounds=args.schedule_rounds,
        )
    return CompressionConfig(
        kind=args.compress,
        ratio=args.compress_ratio,
        error_feedback=not args.no_error_feedback,
        seed=args.seed,
        schedule=schedule,
    )


def train_lm(args):
    args.nodes = args.nodes or 8
    args.steps = args.steps or 50
    args.batch_per_node = args.batch_per_node or 2
    cfg = get_arch(args.arch, smoke=args.smoke)
    model = TransformerLM(cfg)
    k = args.nodes
    seq = args.seq_len

    def loss_fn(params, batch):
        return model.loss(params, batch)

    trainer = DecentralizedTrainer(
        loss_fn,
        num_nodes=k,
        graph=args.graph,
        graph_kwargs={"p": args.p} if args.graph == "erdos_renyi" else {},
        robust=RobustConfig(mu=args.mu, enabled=not args.dsgd),
        lr=args.lr,
        grad_clip=1.0,
        compression=_compression_from_args(args),
    )
    print(f"arch={cfg.name} params={model.num_params():,} nodes={k} "
          f"rho={trainer.rho:.3f} mu={args.mu} robust={not args.dsgd} "
          f"compress={args.compress}")
    state = trainer.init(model.init(jax.random.PRNGKey(args.seed)))
    streams = make_node_token_streams(k, cfg.vocab, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prefix = cfg.frontend_len if cfg.frontend != "token" else 0

    history = []
    t0 = time.time()
    for step in range(args.steps):
        toks = np.stack([
            s.next_batch(args.batch_per_node, seq) for s in streams])
        batch = {"tokens": jnp.asarray(toks)}
        if prefix:
            batch["embeddings"] = jnp.asarray(
                rng.standard_normal((k, args.batch_per_node, prefix,
                                     cfg.d_model)).astype(np.float32) * 0.02)
        state, metrics = trainer.step(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {kk: float(v) for kk, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            history.append(m)
            extra = ""
            if "ef_residual_norm" in m:
                extra = (f" ef_res={m['ef_residual_norm']:.2e}"
                         f" wire_bits={m['wire_bits']:.3e}")
            print(f"step {step:5d} loss_mean={m['loss_mean']:.4f} "
                  f"loss_worst={m['loss_worst']:.4f} "
                  f"disagree={m.get('disagreement', 0):.2e} "
                  f"comm_bytes={m.get('comm_bytes', 0):.3e}" + extra)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state._asdict())
        print(f"checkpoint saved to {args.ckpt_dir}")
    return history


def train_paper(args):
    exp = fmnist_default() if args.paper == "fmnist" else cifar_default()
    k = args.nodes or exp.num_nodes
    steps = args.steps or exp.steps
    if args.paper == "fmnist":
        ds = make_fmnist_like()
        params = mlp_init(jax.random.PRNGKey(args.seed))
        apply_fn = mlp_apply
    else:
        ds = make_cifar_like()
        params = cnn_init(jax.random.PRNGKey(args.seed))
        apply_fn = cnn_apply
    fed = pathological_noniid_partition(ds, k, seed=args.seed)
    x_nodes, y_nodes = fed.per_node_test_sets(n_per_node=200, seed=args.seed)
    trainer = DecentralizedTrainer(
        make_classifier_loss(apply_fn),
        predict_fn=apply_fn,
        num_nodes=k,
        graph="erdos_renyi",
        graph_kwargs={"p": exp.p, "seed": args.seed},
        robust=RobustConfig(mu=args.mu, enabled=not args.dsgd),
        lr=args.lr or exp.lr,
        compression=_compression_from_args(args),
    )
    state = trainer.init(params)
    rng = np.random.default_rng(args.seed)
    bsz = args.batch_per_node or exp.batch_size
    print(f"paper={args.paper} nodes={k} steps={steps} B={bsz} "
          f"lr={trainer.lr} mu={args.mu} rho={trainer.rho:.3f} "
          f"compress={args.compress}")
    for step in range(steps):
        xb, yb = fed.sample_batch(rng, bsz)
        state, metrics = trainer.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
        if step % args.log_every == 0 or step == steps - 1:
            stats = trainer.eval_local_distributions(state, x_nodes, y_nodes)
            print(f"step {step:5d} loss={float(metrics['loss_mean']):.4f} "
                  f"acc_avg={stats['acc_avg']:.3f} "
                  f"acc_worst={stats['acc_worst_dist']:.3f} "
                  f"std={stats['acc_node_std']:.3f} "
                  f"comm_bytes={float(metrics['comm_bytes']):.3e}")
    return state


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--paper", default=None, choices=["fmnist", "cifar"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--nodes", type=int, default=None)
    ap.add_argument("--batch-per-node", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--graph", default="ring")
    ap.add_argument("--p", type=float, default=0.3)
    ap.add_argument("--mu", type=float, default=6.0)
    ap.add_argument("--dsgd", action="store_true", help="disable DR (baseline)")
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8", "int4", "topk", "randk"],
                    help="consensus wire codec (repro.comm)")
    ap.add_argument("--compress-ratio", type=float, default=0.01,
                    help="kept fraction for topk/randk")
    ap.add_argument("--compress-schedule", default="none",
                    choices=["none", "constant", "linear", "adaptive"],
                    help="adapt the codec rate during training "
                         "(repro.comm.schedule): int8->int4 / annealed "
                         "topk ratio, driven by rounds (linear) or the "
                         "error-feedback innovation norm (adaptive)")
    ap.add_argument("--schedule-threshold", type=float, default=0.5,
                    help="adaptive: innovation-norm fraction below which "
                         "the rate anneals")
    ap.add_argument("--schedule-warmup", type=int, default=10,
                    help="adaptive: full-rate rounds before the reference "
                         "norm is latched")
    ap.add_argument("--schedule-rounds", type=int, default=300,
                    help="linear: rounds to anneal full -> aggressive rate")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="ablation: memoryless compression (stalls at the "
                         "quantization noise floor)")
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    if args.lr is None and args.arch:
        args.lr = 0.01
    if args.paper:
        train_paper(args)
    elif args.arch:
        train_lm(args)
    else:
        raise SystemExit("provide --arch <id> or --paper fmnist|cifar")


if __name__ == "__main__":
    main()
