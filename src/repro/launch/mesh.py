"""Production meshes for the multi-pod dry-run.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the 512 placeholder
host devices are requested by dryrun.py's XLA_FLAGS before any jax import.

Mesh geometry (TPU v5e):
  single-pod: (16, 16)     axes ("data", "model")   = 256 chips
  multi-pod:  (2, 16, 16)  axes ("pod", "data", "model") = 512 chips

For decentralized training the graph-node axis is "data" (single-pod, K=16)
or ("pod", "data") (multi-pod, K=32): gossip neighbor exchanges over the
"pod" boundary ride the slow DCN links, which is exactly where DR-DSGD's
sparse communication pattern pays off (DESIGN.md §3).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.utils.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def node_axes(mesh: jax.sharding.Mesh):
    """Mesh axes carrying the decentralized node dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_nodes(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in node_axes(mesh)]))


def data_axes(mesh: jax.sharding.Mesh):
    """Axes used for batch sharding in serving mode."""
    return node_axes(mesh)


def make_debug_mesh(data: int = 4, model: int = 2) -> jax.sharding.Mesh:
    """Small host mesh for unit tests (requires >= data*model host devices)."""
    return make_auto_mesh((data, model), ("data", "model"))
