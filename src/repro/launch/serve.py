"""Batched serving driver: prefill a prompt batch, then autoregressive decode.

The trained consensus model (mean over node replicas, or a checkpoint) serves
requests with a KV/recurrent cache.  On CPU use a smoke config; on TPU the
same step functions are what dryrun.py lowers at the decode_32k / long_500k
shapes.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b --smoke \
      --batch 4 --prompt-len 32 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import TransformerLM


def greedy_generate(model: TransformerLM, params, prompt, gen_len: int,
                    temperature: float = 0.0, seed: int = 0):
    """prompt: (B, S0) int32. Returns (B, gen_len) generated tokens."""
    cfg = model.cfg
    b, s0 = prompt.shape
    cache_len = s0 + gen_len
    cache = model.init_cache(b, cache_len)
    decode = jax.jit(model.decode_step, donate_argnums=(3,))

    # teacher-forced prefill via the decode path (exercises the cache code;
    # a production server would jit model.prefill for the prompt instead)
    logits = None
    for t in range(s0):
        logits, cache = decode(params, prompt[:, t:t + 1], jnp.int32(t), cache)

    key = jax.random.PRNGKey(seed)
    outs = []
    tok = None
    for t in range(gen_len):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        outs.append(tok)
        logits, cache = decode(params, tok[:, None].astype(jnp.int32),
                               jnp.int32(s0 + t), cache)
    return jnp.stack(outs, axis=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"serving {cfg.name}: {model.num_params():,} params, "
          f"batch={args.batch}")
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, args.gen_len,
                          args.temperature, args.seed)
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen_len)
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0][:16]))


if __name__ == "__main__":
    main()
