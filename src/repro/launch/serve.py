"""Serving CLI: static-batch generation or the continuous-batching engine.

The machinery lives in :mod:`repro.serve` — prompt ingestion and the fused
sample+decode loop in ``repro.serve.prefill`` (re-exported here for
compatibility), the paged-pool engine in ``repro.serve.engine``.  This
module is the thin command-line front:

* default: static-batch :func:`timed_generate` — one prompt batch, fused
  in-jit sampling, and *honest* throughput numbers: compile time and
  steady-state are reported separately, prefill and decode each get their
  own tok/s, and prompt tokens are never counted as generated.
* ``--engine``: drive a :class:`repro.serve.ServeEngine` over an open-loop
  Poisson trace (mixed request classes, paged/int8 KV pool).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b --smoke \
      --batch 4 --prompt-len 32 --gen-len 32
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --engine --rate 2.0 --horizon 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import TransformerLM
from repro.serve.prefill import (  # noqa: F401  (compat re-exports)
    greedy_generate,
    merge_prefill_cache,
)
from repro.serve.sampling import sample_tokens


def timed_generate(model: TransformerLM, params, prompt, gen_len: int,
                   temperature: float = 0.0, seed: int = 0,
                   use_prefill: bool = True):
    """:func:`repro.serve.greedy_generate` with phase accounting.

    Returns ``(tokens (B, gen_len), stats)``.  ``stats`` separates what the
    old driver conflated: ``prefill`` vs ``decode`` seconds, and within
    each the first (compiling) invocation vs steady state.  tok/s rates
    divide only the tokens that phase actually processed — prompt tokens
    count toward prefill, generated tokens toward decode.
    """
    cfg = model.cfg
    b, s0 = prompt.shape
    cache_len = s0 + gen_len
    decode = jax.jit(model.decode_step, donate_argnums=(3,))

    def sample_then_decode(params, logits, pos, cache, key, temp):
        key, sub = jax.random.split(key)
        tok = sample_tokens(logits, sub, temp)
        logits, cache = model.decode_step(params, tok[:, None], pos, cache)
        return tok, logits, cache, key

    step = jax.jit(sample_then_decode, donate_argnums=(3,))
    stats = {"prefill": {"compile_s": 0.0, "steady_s": 0.0, "tokens": 0},
             "decode": {"compile_s": 0.0, "steady_s": 0.0, "tokens": 0}}

    if use_prefill and cfg.frontend == "token":
        prefill_fn = jax.jit(model.prefill)
        t0 = time.monotonic()
        logits, pf = prefill_fn(params, {"tokens": prompt})
        jax.block_until_ready(logits)
        t1 = time.monotonic()
        # same shapes -> steady-state program; its outputs are the ones used
        logits, pf = prefill_fn(params, {"tokens": prompt})
        jax.block_until_ready(logits)
        t2 = time.monotonic()
        stats["prefill"] = {"compile_s": max(0.0, (t1 - t0) - (t2 - t1)),
                            "steady_s": t2 - t1, "tokens": b * s0}
        cache = merge_prefill_cache(model, pf, b, cache_len, s0)
    else:
        cache = model.init_cache(b, cache_len)
        logits = None
        t0 = time.monotonic()
        for t in range(s0):
            logits, cache = decode(params, prompt[:, t:t + 1], jnp.int32(t),
                                   cache)
            if t == 0:
                jax.block_until_ready(logits)
                t1 = time.monotonic()
        jax.block_until_ready(logits)
        t2 = time.monotonic()
        stats["prefill"] = {"compile_s": t1 - t0, "steady_s": t2 - t1,
                            "tokens": b * max(0, s0 - 1)}

    key = jax.random.PRNGKey(seed)
    temp = jnp.full((b,), temperature, jnp.float32)
    outs = []
    t0 = time.monotonic()
    t1 = None
    for t in range(gen_len):
        tok, logits, cache, key = step(params, logits, jnp.int32(s0 + t),
                                       cache, key, temp)
        outs.append(tok)
        if t == 0:
            jax.block_until_ready(tok)
            t1 = time.monotonic()
    out = jnp.stack(outs, axis=1)
    jax.block_until_ready(out)
    t2 = time.monotonic()
    stats["decode"] = {"compile_s": (t1 - t0) if t1 is not None else 0.0,
                       "steady_s": (t2 - t1) if t1 is not None else 0.0,
                       "tokens": b * max(0, gen_len - 1)}
    for ph in stats.values():
        ph["tok_s"] = ph["tokens"] / ph["steady_s"] if ph["steady_s"] else 0.0
    return out, stats


def _run_static(args, model, params, cfg) -> None:
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    out, stats = timed_generate(model, params, prompt, args.gen_len,
                                args.temperature, args.seed,
                                use_prefill=not args.no_prefill)
    pf, dc = stats["prefill"], stats["decode"]
    print(f"generated {out.shape}")
    print(f"prefill: {pf['tokens']} prompt tok, compile {pf['compile_s']:.2f}s,"
          f" steady {pf['steady_s']:.3f}s -> {pf['tok_s']:.1f} tok/s")
    print(f"decode:  {dc['tokens']} new tok,    compile {dc['compile_s']:.2f}s,"
          f" steady {dc['steady_s']:.3f}s -> {dc['tok_s']:.1f} tok/s")
    print("sample:", np.asarray(out[0][:16]))


def _run_engine(args, model, params, cfg) -> None:
    from repro.obs import MetricsSink
    from repro.serve import SMOKE_CLASSES, ServeEngine, poisson_trace

    # context bound from the traffic classes' worst case, not --prompt-len
    max_len = max(c.prompt_len + c.gen_max for c in SMOKE_CLASSES)
    engine = ServeEngine(
        model, params, max_batch=args.batch, max_len=max_len,
        page_size=args.page_size, quantized=args.int8_kv, seed=args.seed,
        sink=MetricsSink(args.log_dir) if args.log_dir else None,
        log_every=args.log_every)
    trace = poisson_trace(SMOKE_CLASSES, rate=args.rate,
                          horizon=args.horizon, vocab=cfg.vocab,
                          seed=args.seed)
    report = engine.run(trace, clock="steps" if args.smoke else "wall")
    dc = report["decode"]
    print(f"engine: {report['completed']}/{report['admitted']} requests, "
          f"{report['steps']} steps in {report['wall_s']:.2f}s")
    print(f"decode: compile {dc['compile_s']:.2f}s, steady "
          f"{dc['steady_s']:.3f}s -> {dc['tok_s']:.1f} tok/s "
          f"({dc['steady_tokens']} tok)")
    # latency comes from the engine's finished trace records — the same
    # accounting bench_serve and `python -m repro.obs report` use
    lat = report["latency"]
    if lat["requests"]:
        line = (f"latency: ttft p50 {lat['ttft_p50_s']:.3f}s "
                f"p99 {lat['ttft_p99_s']:.3f}s")
        if "per_token_p50_s" in lat:
            line += (f", per-token p50 {lat['per_token_p50_s'] * 1e3:.1f}ms "
                     f"p99 {lat['per_token_p99_s'] * 1e3:.1f}ms")
        print(line)
        for cls, d in lat["per_class"].items():
            print(f"  class {cls}: {d['requests']} req, "
                  f"ttft p50 {d['ttft_p50_s']:.3f}s p99 {d['ttft_p99_s']:.3f}s")
    print(f"programs: {report['programs']}")
    engine.sink.close()
    if engine.sink.path:
        print(f"telemetry: {engine.sink.path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-prefill", action="store_true",
                    help="force the token-by-token decode-path prompt loop")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine over a Poisson trace")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="engine: arrivals per clock unit")
    ap.add_argument("--horizon", type=float, default=16.0,
                    help="engine: trace length in clock units")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--log-dir", default=None)
    ap.add_argument("--log-every", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"serving {cfg.name}: {model.num_params():,} params, "
          f"batch={args.batch} engine={args.engine}")
    if args.engine:
        _run_engine(args, model, params, cfg)
    else:
        _run_static(args, model, params, cfg)


if __name__ == "__main__":
    main()
