"""Batched serving driver: prefill a prompt batch, then autoregressive decode.

The trained consensus model (mean over node replicas, or a checkpoint) serves
requests with a KV/recurrent cache.  On CPU use a smoke config; on TPU the
same step functions are what dryrun.py lowers at the decode_32k / long_500k
shapes.

The prompt runs through ONE jitted ``model.prefill`` call (full-sequence
chunked attention, O(S0) compute in a single program) and its per-layer
caches are scattered into the decode cache; the old O(S0)-dispatch
token-by-token decode loop over the prompt is kept only as the fallback for
prefix-frontend architectures (``--no-prefill`` forces it for A/B testing —
the two paths generate identical tokens, see tests/test_serve.py).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b --smoke \
      --batch 4 --prompt-len 32 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import TransformerLM


def _place_layer(blk: str, dst, src, s0: int, grouped: bool):
    """Scatter one layer's prefill cache into its allocated decode cache.

    attn/swa KV leaves are (B, T, kvh, hd) (plus a leading group axis when
    ``grouped``): a prompt shorter than the buffer lands at slots
    ``0..s0-1``; a full sliding-window ring buffer (prefill keeps the last
    ``window`` positions) is rolled so position p sits at slot ``p % window``
    — exactly where ``attention_decode`` will read/write next.  Recurrent
    states (mamba/rwkv) are already the post-prompt state and pass through.
    """
    if blk not in ("attn", "swa"):
        return src

    ax = 2 if grouped else 1  # the sequence axis of the KV leaves

    def leaf(d, s):
        s = s.astype(d.dtype)
        t, sl = d.shape[ax], s.shape[ax]
        if sl == t:
            return jnp.roll(s, s0 % t, axis=ax)
        return jax.lax.dynamic_update_slice(d, s, (0,) * d.ndim)

    return jax.tree.map(leaf, dst, src)


def merge_prefill_cache(model: TransformerLM, prefill_caches, batch: int,
                        cache_len: int, s0: int):
    """Build the decode cache for ``cache_len`` from ``model.prefill`` output.

    ``prefill_caches`` is the ``(head_caches, group_caches)`` pair returned
    by ``model.prefill``; the result has the ``model.init_cache`` structure
    with the prompt's KV/state in place, ready for ``decode_step`` at
    ``pos = s0``.
    """
    cfg = model.cfg
    head_pf, group_pf = prefill_caches
    cache = model.init_cache(batch, cache_len)
    head = [
        _place_layer(blk, cache["head"][i], head_pf[i], s0, grouped=False)
        for i, (blk, _) in enumerate(cfg.head_layers())
    ]
    groups = {
        f"l{i}": _place_layer(blk, cache["groups"][f"l{i}"],
                              group_pf[f"l{i}"], s0, grouped=True)
        for i, (blk, _) in enumerate(cfg.group_pattern())
    }
    return {"head": head, "groups": groups}


def greedy_generate(model: TransformerLM, params, prompt, gen_len: int,
                    temperature: float = 0.0, seed: int = 0,
                    use_prefill: bool = True):
    """prompt: (B, S0) int32. Returns (B, gen_len) generated tokens."""
    cfg = model.cfg
    b, s0 = prompt.shape
    cache_len = s0 + gen_len
    decode = jax.jit(model.decode_step, donate_argnums=(3,))

    if use_prefill and cfg.frontend == "token":
        # one compiled program for the whole prompt instead of S0 dispatches
        logits, pf_caches = jax.jit(model.prefill)(params,
                                                   {"tokens": prompt})
        cache = merge_prefill_cache(model, pf_caches, b, cache_len, s0)
    else:
        # prefix-frontend archs (or --no-prefill): teacher-forced prefill
        # via the decode path, one token at a time
        cache = model.init_cache(b, cache_len)
        logits = None
        for t in range(s0):
            logits, cache = decode(params, prompt[:, t:t + 1], jnp.int32(t),
                                   cache)

    key = jax.random.PRNGKey(seed)
    outs = []
    tok = None
    for t in range(gen_len):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        outs.append(tok)
        logits, cache = decode(params, tok[:, None].astype(jnp.int32),
                               jnp.int32(s0 + t), cache)
    return jnp.stack(outs, axis=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-prefill", action="store_true",
                    help="force the token-by-token decode-path prompt loop")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"serving {cfg.name}: {model.num_params():,} params, "
          f"batch={args.batch} prefill={not args.no_prefill}")
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, args.gen_len,
                          args.temperature, args.seed,
                          use_prefill=not args.no_prefill)
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen_len)
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0][:16]))


if __name__ == "__main__":
    main()
