"""The paper's primary contribution: KL-DRO robust decentralized SGD.

robust.py     — the KL-regularized DRO objective and the exp(l/mu)/mu scale
consensus.py  — mixing operators (dense einsum / ppermute gossip / hierarchical)
drdsgd.py     — DR-DSGD & DSGD train-step builders over node-stacked pytrees
api.py        — DecentralizedTrainer high-level API
"""

from repro.comm import CommState, CompressionConfig, ScheduleConfig
from repro.core.robust import (
    RobustConfig,
    robust_scale,
    robust_objective,
    mixture_weights,
)
from repro.core.consensus import (
    Mixer,
    make_dense_mixer,
    make_gossip_mixer,
    make_hierarchical_mixer,
    make_identity_mixer,
    repeat_mixer,
)
from repro.core.drdsgd import (
    DecentralizedState,
    TrainStepConfig,
    build_train_step,
    build_eval_step,
    init_state,
    replicate_params,
)
from repro.core.api import DecentralizedTrainer

__all__ = [
    "CommState", "CompressionConfig", "ScheduleConfig",
    "RobustConfig", "robust_scale", "robust_objective", "mixture_weights",
    "Mixer", "make_dense_mixer", "make_gossip_mixer",
    "make_hierarchical_mixer", "make_identity_mixer", "repeat_mixer",
    "DecentralizedState", "TrainStepConfig", "build_train_step",
    "build_eval_step", "init_state", "replicate_params",
    "DecentralizedTrainer",
]
