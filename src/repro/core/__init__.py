"""The paper's primary contribution: KL-DRO robust decentralized SGD.

robust.py     — the KL-regularized DRO objective and the exp(l/mu)/mu scale
consensus.py  — mixing operators (dense einsum / ppermute gossip / hierarchical)
                behind the uniform stateful Mixer protocol (repro.comm.protocol)
drdsgd.py     — DR-DSGD & DSGD train-step builders over node-stacked pytrees
api.py        — DecentralizedTrainer high-level API (step + scan-based run)
spec.py       — TrainerSpec: declarative construction shared by CLI/benchmarks
"""

from repro.comm import (
    CommMetrics,
    CommState,
    CompressionConfig,
    Mixer,
    ScheduleConfig,
)
from repro.core.robust import (
    RobustConfig,
    robust_scale,
    robust_objective,
    mixture_weights,
)
from repro.core.consensus import (
    DenseMixer,
    GossipMixer,
    HierarchicalMixer,
    IdentityMixer,
    RepeatMixer,
    make_dense_mixer,
    make_gossip_mixer,
    make_hierarchical_mixer,
    make_identity_mixer,
    repeat_mixer,
)
from repro.core.drdsgd import (
    DecentralizedState,
    TrainStepConfig,
    build_train_step,
    build_eval_step,
    init_state,
    replicate_params,
)
from repro.core.api import DecentralizedTrainer, run_segments
from repro.core.spec import (
    TrainerSpec,
    add_compression_cli_args,
    add_dynamics_cli_args,
    add_obs_cli_args,
    compression_from_args,
)

__all__ = [
    "CommMetrics", "CommState", "CompressionConfig", "ScheduleConfig",
    "RobustConfig", "robust_scale", "robust_objective", "mixture_weights",
    "Mixer", "DenseMixer", "GossipMixer", "HierarchicalMixer",
    "IdentityMixer", "RepeatMixer",
    "make_dense_mixer", "make_gossip_mixer",
    "make_hierarchical_mixer", "make_identity_mixer", "repeat_mixer",
    "DecentralizedState", "TrainStepConfig", "build_train_step",
    "build_eval_step", "init_state", "replicate_params",
    "DecentralizedTrainer", "run_segments",
    "TrainerSpec", "add_compression_cli_args", "add_dynamics_cli_args",
    "add_obs_cli_args", "compression_from_args",
]
