"""Consensus (mixing) operators: θ ← θ·W lowered four ways for TPU.

All operators act on *node-stacked* pytrees: every leaf has a leading axis K
(the decentralized node count).  Numerically they all implement the same
doubly-stochastic mixing; they differ in the collectives XLA emits:

* ``make_dense_mixer``   — einsum over the node axis. Simple, works anywhere
  (including CPU simulation with any K); under pjit it lowers to an
  all-gather of O(K·P) bytes over the node mesh axis. Paper-faithful baseline.
* ``make_gossip_mixer``  — shard_map + one ``lax.ppermute`` per matching of
  the edge-colored graph. O(deg·P) bytes; matchings of a ring/torus map to
  the physical neighbor links of the TPU interconnect. This is the
  communication-efficient lowering that realizes the paper's
  decentralization benefit on real hardware.
* ``make_hierarchical_mixer`` — beyond-paper: psum-mean over an inner
  ``replica`` mesh axis (data-parallel replicas inside each node) composed
  with gossip over the outer node axis. Lets K ≪ data-parallel world size so
  that per-chip parameter memory stays bounded for multi-100B models.
* ``make_hub_mixer``     — the federated lowering: every consensus round is
  the exact server average (W = 11ᵀ/K, the ρ=0 endpoint of the mixing-rate
  axis).  Stacked under ``LocalUpdateMixer`` this is FedAvg; with
  ``gradient_tracking=True`` the tracker correction is exactly SCAFFOLD's
  control variate (c_i = global window progress − local window progress).

Since the Topology × Transport × Wire refactor every class here is a thin
constructor shim assembling a layer stack behind
:class:`repro.comm.composed.ComposedMixer` (see that module for the layer
contract); the shims keep the historical names, signatures,
``obs:consensus/<name>`` scopes and bit-exact trajectories
(``tests/data/mixer_anchors.json``).

Protocol v2: every factory returns a :class:`repro.comm.protocol.Mixer`
with ONE calling convention, compressed or not::

    comm  = mixer.init_state(params)               # CommState
    theta, comm = mixer(theta, comm, round=step)   # one consensus round

Uncompressed mixers carry the *trivial* ``CommState`` (no public copies, a
never-consumed PRNG key) and stamp their static full-precision ``wire_bits``
into it each round; ``mixer.state_specs(param_specs)`` gives matching
PartitionSpecs for pjit.  Every factory accepts a ``compression:
CompressionConfig`` (``repro.comm``): when enabled it returns the
corresponding compressed mixer that gossips error-feedback-corrected
compressed innovations instead of raw parameters — same protocol, richer
state.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.comm import CompressedDenseMixer, CompressedGossipMixer, CompressionConfig
from repro.comm.composed import ComposedMixer
from repro.comm.protocol import Mixer
from repro.comm.topology import StarTopology, StaticTopology
from repro.comm.transport import (  # noqa: F401  (legacy import surface)
    DenseTransport,
    GossipTransport,
    StarTransport,
    _bcast,
    gossip_mix_local,
)
from repro.comm.wire import IdentityWire
from repro.graphs.mixing import MixingDecomposition

AxisName = str | tuple[str, ...]


def _compression_enabled(compression: CompressionConfig | None) -> bool:
    return compression is not None and compression.enabled


class DenseMixer(ComposedMixer):
    """θ_i ← Σ_j W_ij θ_j via einsum along the leading node axis."""

    def __init__(self, w: np.ndarray, compute_dtype=jnp.float32):
        super().__init__(StaticTopology(w), DenseTransport(compute_dtype),
                         IdentityWire())


def make_dense_mixer(w: np.ndarray, compute_dtype=jnp.float32,
                     compression: CompressionConfig | None = None) -> Mixer:
    """Dense einsum mixing (or its compressed counterpart)."""
    if _compression_enabled(compression):
        return CompressedDenseMixer(w, compression)
    return DenseMixer(w, compute_dtype)


class GossipMixer(ComposedMixer):
    """Sparse gossip mixing: one collective-permute per graph matching.

    ``param_specs`` is a pytree of PartitionSpecs matching the *node-stacked*
    params (leading dim partitioned over ``node_axis``); it is used for
    shard_map in/out specs so tensor-parallel dims stay sharded.
    """

    def __init__(self, decomp: MixingDecomposition, mesh,
                 node_axis: AxisName, param_specs):
        super().__init__(
            None, GossipTransport(decomp, mesh, node_axis, param_specs),
            IdentityWire())


def make_gossip_mixer(
    decomp: MixingDecomposition,
    mesh,
    node_axis: AxisName,
    param_specs,
    compression: CompressionConfig | None = None,
) -> Mixer:
    """Gossip mixing over the mesh node axis (or its compressed counterpart)."""
    if _compression_enabled(compression):
        return CompressedGossipMixer(decomp, mesh, node_axis, param_specs,
                                     compression)
    return GossipMixer(decomp, mesh, node_axis, param_specs)


class HierarchicalMixer(GossipMixer):
    """FSDP-inside / gossip-across: psum-mean over ``replica_axis`` then gossip.

    Node-stacked leaves are *replicated* across ``replica_axis`` (each node's
    replicas hold divergent gradient contributions that are averaged here),
    then the per-node consensus step runs over ``node_axis``.
    """

    def __init__(self, decomp, mesh, node_axis, replica_axis: str,
                 param_specs):
        ComposedMixer.__init__(
            self, None,
            GossipTransport(decomp, mesh, node_axis, param_specs,
                            replica_axis=replica_axis),
            IdentityWire())
        self._r_size = mesh.shape[replica_axis]


def make_hierarchical_mixer(
    decomp: MixingDecomposition,
    mesh,
    node_axis: AxisName,
    replica_axis: str,
    param_specs,
    compression: CompressionConfig | None = None,
) -> Mixer:
    """Hierarchical replica-average + gossip (or its compressed counterpart)."""
    if _compression_enabled(compression):
        return CompressedGossipMixer(decomp, mesh, node_axis, param_specs,
                                     compression, replica_axis=replica_axis)
    return HierarchicalMixer(decomp, mesh, node_axis, replica_axis, param_specs)


class IdentityMixer(ComposedMixer):
    """No communication — for ablations (pure local SGD)."""

    def __init__(self):
        super().__init__(None, None, IdentityWire())


def make_identity_mixer() -> Mixer:
    return IdentityMixer()


class HubMixer(ComposedMixer):
    """Hub-and-spoke (federated) consensus: the exact global average.

    Star topology × star transport: each round every node uploads its block
    and downloads the mean — one round reaches consensus exactly (ρ = 0).
    ``LocalUpdateMixer(HubMixer(k), H)`` is FedAvg with H local steps;
    adding ``gradient_tracking=True`` yields the SCAFFOLD control variate
    (the tracker update (Δ̄ − Δ_i)/H under W = 11ᵀ/K is exactly c_i).
    """

    def __init__(self, k: int):
        super().__init__(StarTopology(k), StarTransport(k), IdentityWire())


def make_hub_mixer(k: int,
                   compression: CompressionConfig | None = None) -> Mixer:
    """Federated server averaging (or its compressed counterpart).

    The compressed hub rides the dense transport with the star W — the
    codec round re-mixes the full public-copy matrix, which with W = 11ᵀ/K
    is exactly "server averages the reconstructed client innovations".
    """
    if _compression_enabled(compression):
        return CompressedDenseMixer(np.full((k, k), 1.0 / k), compression)
    return HubMixer(k)


class RepeatMixer(Mixer):
    """θ ← θ·W^rounds: multiple gossip rounds per optimizer step.

    Theorem 1's consensus term contracts like ρ^rounds, so m rounds on a
    sparse graph can substitute for a denser graph at m× the mixing wire —
    a knob for trading interconnect bytes against the convergence constant
    (see EXPERIMENTS.md §Perf A4 for the measured trade).
    """

    def __init__(self, mixer: Mixer, rounds: int):
        if rounds < 1:
            raise ValueError("rounds must be >= 1")
        self.inner = mixer
        self.rounds = rounds

    @property
    def compression(self):
        return self.inner.compression

    @property
    def traced_wire(self) -> bool:
        return self.inner.traced_wire

    def init_state(self, params):
        return self.inner.init_state(params)

    def state_specs(self, param_specs):
        return self.inner.state_specs(param_specs)

    def __call__(self, theta, state, *, round=None):
        total_bits = jnp.float32(0.0)
        for _ in range(self.rounds):
            theta, state = self.inner(theta, state, round=round)
            total_bits = total_bits + state.wire_bits
        # wire_bits is per-*step* accounting: sum the inner rounds
        return theta, state._replace(wire_bits=total_bits)

    def bytes_per_round(self, params) -> int:
        return self.rounds * self.inner.bytes_per_round(params)

    def wire_dtype_bytes(self, params):
        inner = self.inner.wire_dtype_bytes(params)
        if inner is None:
            return None
        # the python loop unrolls: the HLO carries `rounds` copies of the
        # inner round's collectives
        return {dt: self.rounds * b for dt, b in inner.items()}


def repeat_mixer(mixer: Mixer, rounds: int) -> Mixer:
    return RepeatMixer(mixer, rounds)
