"""Consensus (mixing) operators: θ ← θ·W lowered three ways for TPU.

All operators act on *node-stacked* pytrees: every leaf has a leading axis K
(the decentralized node count).  Numerically they all implement the same
doubly-stochastic mixing; they differ in the collectives XLA emits:

* ``make_dense_mixer``   — einsum over the node axis. Simple, works anywhere
  (including CPU simulation with any K); under pjit it lowers to an
  all-gather of O(K·P) bytes over the node mesh axis. Paper-faithful baseline.
* ``make_gossip_mixer``  — shard_map + one ``lax.ppermute`` per matching of
  the edge-colored graph. O(deg·P) bytes; matchings of a ring/torus map to
  the physical neighbor links of the TPU interconnect. Requires
  K == prod(mesh node axes). This is the communication-efficient lowering
  that realizes the paper's decentralization benefit on real hardware.
* ``make_hierarchical_mixer`` — beyond-paper: psum-mean over an inner
  ``replica`` mesh axis (data-parallel replicas inside each node) composed
  with gossip over the outer node axis. Lets K ≪ data-parallel world size so
  that per-chip parameter memory stays bounded for multi-100B models.

Every factory accepts a ``compression: CompressionConfig`` (``repro.comm``):
when enabled it returns the corresponding *stateful* compressed mixer
(``mix(theta, CommState) -> (theta, CommState)``, ``stateful = True``) that
gossips error-feedback-corrected compressed innovations instead of raw
parameters.  Plain mixers stay simple ``theta -> theta`` callables and carry
a ``bytes_per_round`` estimator for the per-step ``comm_bytes`` metric.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CompressedDenseMixer, CompressedGossipMixer, CompressionConfig
from repro.graphs.mixing import MixingDecomposition
from repro.utils.compat import shard_map
from repro.utils.tree import tree_bytes

Mixer = Callable[[Any], Any]  # node-stacked pytree -> node-stacked pytree

AxisName = str | tuple[str, ...]


def _compression_enabled(compression: CompressionConfig | None) -> bool:
    return compression is not None and compression.enabled


def make_dense_mixer(w: np.ndarray, compute_dtype=jnp.float32,
                     compression: CompressionConfig | None = None) -> Mixer:
    """θ_i ← Σ_j W_ij θ_j via einsum along the leading node axis."""
    if _compression_enabled(compression):
        return CompressedDenseMixer(w, compression)
    w = jnp.asarray(np.asarray(w), dtype=compute_dtype)

    def mix(theta):
        def leaf(x):
            out = jnp.einsum(
                "kl,l...->k...", w, x.astype(compute_dtype),
                precision=jax.lax.Precision.HIGHEST,
            )
            return out.astype(x.dtype)

        return jax.tree.map(leaf, theta)

    # uncompressed round: every node injects its full param block once
    mix.bytes_per_round = tree_bytes
    return mix


def _bcast(v: jax.Array, like: jax.Array) -> jax.Array:
    """Reshape a (k_local,) weight vector to broadcast over a (k_local, ...) leaf."""
    return v.reshape(v.shape + (1,) * (like.ndim - 1))


def gossip_mix_local(theta_local, self_w, match_ws, perms, axis: AxisName):
    """The per-shard body of the gossip mixer (must run inside shard_map).

    Args:
      theta_local: pytree of (k_local, ...) local node blocks.
      self_w: (k_local,) diagonal weights for the local nodes.
      match_ws: list of (k_local,) per-matching edge weights.
      perms: list of ppermute (src, dst) pair lists (static python).
      axis: mesh axis name(s) carrying the node dimension.

    Wire compression is not an ad-hoc dtype cast here anymore: compressed
    gossip (bf16 / int8 / int4 / topk / randk + error feedback) lives in
    ``repro.comm.mixers.CompressedGossipMixer``.
    """

    def leaf(x):
        acc = x.astype(jnp.float32) * _bcast(self_w, x)
        for pw, perm in zip(match_ws, perms):
            recv = jax.lax.ppermute(x, axis, perm)
            acc = acc + recv.astype(jnp.float32) * _bcast(pw, x)
        return acc.astype(x.dtype)

    return jax.tree.map(leaf, theta_local)


def _gossip_bytes_per_round(decomp: MixingDecomposition, k: int):
    sends = sum(len(pairs) for pairs in decomp.ppermute_pairs())

    def estimate(params):
        return sends * tree_bytes(params) // k

    return estimate


def make_gossip_mixer(
    decomp: MixingDecomposition,
    mesh: jax.sharding.Mesh,
    node_axis: AxisName,
    param_specs,
    compression: CompressionConfig | None = None,
) -> Mixer:
    """Sparse gossip mixing: one collective-permute per graph matching.

    ``param_specs`` is a pytree of PartitionSpecs matching the *node-stacked*
    params (leading dim partitioned over ``node_axis``); it is used for
    shard_map in/out specs so tensor-parallel dims stay sharded.
    """
    if _compression_enabled(compression):
        return CompressedGossipMixer(decomp, mesh, node_axis, param_specs,
                                     compression)
    axes = (node_axis,) if isinstance(node_axis, str) else tuple(node_axis)
    k_mesh = int(np.prod([mesh.shape[a] for a in axes]))
    k = decomp.self_weights.shape[0]
    if k != k_mesh:
        raise ValueError(
            f"gossip mixer needs K == mesh node size: K={k}, mesh {axes}={k_mesh}"
        )
    axis: AxisName = node_axis if isinstance(node_axis, str) else tuple(node_axis)
    self_w = jnp.asarray(decomp.self_weights, jnp.float32)
    match_ws = [jnp.asarray(w, jnp.float32) for w in decomp.matching_weights]
    perms = decomp.ppermute_pairs()
    p_node = jax.sharding.PartitionSpec(axis)

    def mix(theta):
        body = partial(gossip_mix_local, axis=axis, perms=perms)
        return shard_map(
            lambda t, sw, mws: body(t, sw, mws),
            mesh=mesh,
            in_specs=(param_specs, p_node, [p_node] * len(match_ws)),
            out_specs=param_specs,
        )(theta, self_w, list(match_ws))

    mix.bytes_per_round = _gossip_bytes_per_round(decomp, k)
    return mix


def make_hierarchical_mixer(
    decomp: MixingDecomposition,
    mesh: jax.sharding.Mesh,
    node_axis: AxisName,
    replica_axis: str,
    param_specs,
    compression: CompressionConfig | None = None,
) -> Mixer:
    """FSDP-inside / gossip-across: psum-mean over ``replica_axis`` then gossip.

    Node-stacked leaves are *replicated* across ``replica_axis`` (each node's
    replicas hold divergent gradient contributions that are averaged here),
    then the per-node consensus step runs over ``node_axis``.
    """
    if _compression_enabled(compression):
        return CompressedGossipMixer(decomp, mesh, node_axis, param_specs,
                                     compression, replica_axis=replica_axis)
    axes = (node_axis,) if isinstance(node_axis, str) else tuple(node_axis)
    k_mesh = int(np.prod([mesh.shape[a] for a in axes]))
    k = decomp.self_weights.shape[0]
    if k != k_mesh:
        raise ValueError(f"K={k} != mesh node size {k_mesh}")
    axis: AxisName = node_axis if isinstance(node_axis, str) else tuple(node_axis)
    self_w = jnp.asarray(decomp.self_weights, jnp.float32)
    match_ws = [jnp.asarray(w, jnp.float32) for w in decomp.matching_weights]
    perms = decomp.ppermute_pairs()
    p_node = jax.sharding.PartitionSpec(axis)
    r_size = mesh.shape[replica_axis]

    def mix(theta):
        def body(t, sw, mws):
            # average the within-node replicas (plain DP all-reduce over ICI)
            t = jax.tree.map(
                lambda x: jax.lax.psum(x, replica_axis) / r_size, t
            )
            return gossip_mix_local(t, sw, mws, perms, axis)

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(param_specs, p_node, [p_node] * len(match_ws)),
            out_specs=param_specs,
        )(theta, self_w, list(match_ws))

    mix.bytes_per_round = _gossip_bytes_per_round(decomp, k)
    return mix


def make_identity_mixer() -> Mixer:
    """No communication — for ablations (pure local SGD)."""

    def mix(theta):
        return theta

    mix.bytes_per_round = lambda params: 0
    return mix


def repeat_mixer(mixer: Mixer, rounds: int) -> Mixer:
    """θ ← θ·W^rounds: multiple gossip rounds per optimizer step.

    Theorem 1's consensus term contracts like ρ^rounds, so m rounds on a
    sparse graph can substitute for a denser graph at m× the mixing wire —
    a knob for trading interconnect bytes against the convergence constant
    (see EXPERIMENTS.md §Perf A4 for the measured trade).
    """
    if rounds < 1:
        raise ValueError("rounds must be >= 1")
    if getattr(mixer, "stateful", False):
        def mix_stateful(theta, comm_state):
            total_bits = jnp.float32(0.0)
            for _ in range(rounds):
                theta, comm_state = mixer(theta, comm_state)
                total_bits = total_bits + comm_state.wire_bits
            # wire_bits is per-*step* accounting: sum the inner rounds
            return theta, comm_state._replace(wire_bits=total_bits)

        mix_stateful.stateful = True
        mix_stateful.init_state = mixer.init_state
        mix_stateful.state_specs = getattr(mixer, "state_specs", None)
        mix_stateful.bytes_per_round = (
            lambda params: rounds * mixer.bytes_per_round(params))
        return mix_stateful

    def mix(theta):
        for _ in range(rounds):
            theta = mixer(theta)
        return theta

    inner_bytes = getattr(mixer, "bytes_per_round", tree_bytes)
    mix.bytes_per_round = lambda params: rounds * inner_bytes(params)
    return mix
