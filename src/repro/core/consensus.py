"""Consensus (mixing) operators: θ ← θ·W lowered three ways for TPU.

All operators act on *node-stacked* pytrees: every leaf has a leading axis K
(the decentralized node count).  Numerically they all implement the same
doubly-stochastic mixing; they differ in the collectives XLA emits:

* ``make_dense_mixer``   — einsum over the node axis. Simple, works anywhere
  (including CPU simulation with any K); under pjit it lowers to an
  all-gather of O(K·P) bytes over the node mesh axis. Paper-faithful baseline.
* ``make_gossip_mixer``  — shard_map + one ``lax.ppermute`` per matching of
  the edge-colored graph. O(deg·P) bytes; matchings of a ring/torus map to
  the physical neighbor links of the TPU interconnect. Requires
  K == prod(mesh node axes). This is the communication-efficient lowering
  that realizes the paper's decentralization benefit on real hardware.
* ``make_hierarchical_mixer`` — beyond-paper: psum-mean over an inner
  ``replica`` mesh axis (data-parallel replicas inside each node) composed
  with gossip over the outer node axis. Lets K ≪ data-parallel world size so
  that per-chip parameter memory stays bounded for multi-100B models.

Protocol v2: every factory returns a :class:`repro.comm.protocol.Mixer`
with ONE calling convention, compressed or not::

    comm  = mixer.init_state(params)               # CommState
    theta, comm = mixer(theta, comm, round=step)   # one consensus round

Uncompressed mixers carry the *trivial* ``CommState`` (no public copies, a
never-consumed PRNG key) and stamp their static full-precision ``wire_bits``
into it each round; ``mixer.state_specs(param_specs)`` gives matching
PartitionSpecs for pjit.  Every factory accepts a ``compression:
CompressionConfig`` (``repro.comm``): when enabled it returns the
corresponding compressed mixer that gossips error-feedback-corrected
compressed innovations instead of raw parameters — same protocol, richer
state.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CompressedDenseMixer, CompressedGossipMixer, CompressionConfig
from repro.comm.protocol import Mixer
from repro.graphs.mixing import MixingDecomposition
from repro.utils.compat import shard_map
from repro.utils.tree import tree_bytes

AxisName = str | tuple[str, ...]


def _compression_enabled(compression: CompressionConfig | None) -> bool:
    return compression is not None and compression.enabled


class DenseMixer(Mixer):
    """θ_i ← Σ_j W_ij θ_j via einsum along the leading node axis."""

    def __init__(self, w: np.ndarray, compute_dtype=jnp.float32):
        self.w = jnp.asarray(np.asarray(w), dtype=compute_dtype)
        self.compute_dtype = compute_dtype

    def _mix(self, theta):
        def leaf(x):
            out = jnp.einsum(
                "kl,l...->k...", self.w, x.astype(self.compute_dtype),
                precision=jax.lax.Precision.HIGHEST,
            )
            return out.astype(x.dtype)

        return jax.tree.map(leaf, theta)

    def bytes_per_round(self, params) -> int:
        # uncompressed round: every node injects its full param block once
        return tree_bytes(params)


def make_dense_mixer(w: np.ndarray, compute_dtype=jnp.float32,
                     compression: CompressionConfig | None = None) -> Mixer:
    """Dense einsum mixing (or its compressed counterpart)."""
    if _compression_enabled(compression):
        return CompressedDenseMixer(w, compression)
    return DenseMixer(w, compute_dtype)


def _bcast(v: jax.Array, like: jax.Array) -> jax.Array:
    """Reshape a (k_local,) weight vector to broadcast over a (k_local, ...) leaf."""
    return v.reshape(v.shape + (1,) * (like.ndim - 1))


def gossip_mix_local(theta_local, self_w, match_ws, perms, axis: AxisName):
    """The per-shard body of the gossip mixer (must run inside shard_map).

    Args:
      theta_local: pytree of (k_local, ...) local node blocks.
      self_w: (k_local,) diagonal weights for the local nodes.
      match_ws: list of (k_local,) per-matching edge weights.
      perms: list of ppermute (src, dst) pair lists (static python).
      axis: mesh axis name(s) carrying the node dimension.

    Wire compression is not an ad-hoc dtype cast here anymore: compressed
    gossip (bf16 / int8 / int4 / topk / randk + error feedback) lives in
    ``repro.comm.mixers.CompressedGossipMixer``.
    """

    def leaf(x):
        acc = x.astype(jnp.float32) * _bcast(self_w, x)
        for pw, perm in zip(match_ws, perms):
            recv = jax.lax.ppermute(x, axis, perm)
            acc = acc + recv.astype(jnp.float32) * _bcast(pw, x)
        return acc.astype(x.dtype)

    return jax.tree.map(leaf, theta_local)


class GossipMixer(Mixer):
    """Sparse gossip mixing: one collective-permute per graph matching.

    ``param_specs`` is a pytree of PartitionSpecs matching the *node-stacked*
    params (leading dim partitioned over ``node_axis``); it is used for
    shard_map in/out specs so tensor-parallel dims stay sharded.
    """

    def __init__(self, decomp: MixingDecomposition, mesh: jax.sharding.Mesh,
                 node_axis: AxisName, param_specs):
        axes = (node_axis,) if isinstance(node_axis, str) else tuple(node_axis)
        k_mesh = int(np.prod([mesh.shape[a] for a in axes]))
        k = decomp.self_weights.shape[0]
        if k != k_mesh:
            raise ValueError(
                f"gossip mixer needs K == mesh node size: K={k}, "
                f"mesh {axes}={k_mesh}")
        self.k = k
        self.mesh = mesh
        self.axis: AxisName = (node_axis if isinstance(node_axis, str)
                               else tuple(node_axis))
        self.param_specs = param_specs
        self.self_w = jnp.asarray(decomp.self_weights, jnp.float32)
        self.match_ws = [jnp.asarray(w, jnp.float32)
                         for w in decomp.matching_weights]
        self.perms = decomp.ppermute_pairs()
        self._p_node = jax.sharding.PartitionSpec(self.axis)

    def _mix(self, theta):
        body = partial(gossip_mix_local, axis=self.axis, perms=self.perms)
        return shard_map(
            lambda t, sw, mws: body(t, sw, mws),
            mesh=self.mesh,
            in_specs=(self.param_specs, self._p_node,
                      [self._p_node] * len(self.match_ws)),
            out_specs=self.param_specs,
        )(theta, self.self_w, list(self.match_ws))

    def bytes_per_round(self, params) -> int:
        sends = sum(len(pairs) for pairs in self.perms)
        return sends * tree_bytes(params) // self.k

    def wire_dtype_bytes(self, params) -> dict[str, float]:
        """Physical collective-permute bytes per round by dtype: every
        matching link moves each leaf shard at its own precision."""
        from repro.utils.hlo import hlo_dtype_name

        sends = sum(len(pairs) for pairs in self.perms)
        out: dict[str, float] = {}
        for x in jax.tree.leaves(params):
            dt = hlo_dtype_name(x.dtype)
            out[dt] = out.get(dt, 0.0) \
                + sends * (x.size // self.k) * x.dtype.itemsize
        return out


def make_gossip_mixer(
    decomp: MixingDecomposition,
    mesh: jax.sharding.Mesh,
    node_axis: AxisName,
    param_specs,
    compression: CompressionConfig | None = None,
) -> Mixer:
    """Gossip mixing over the mesh node axis (or its compressed counterpart)."""
    if _compression_enabled(compression):
        return CompressedGossipMixer(decomp, mesh, node_axis, param_specs,
                                     compression)
    return GossipMixer(decomp, mesh, node_axis, param_specs)


class HierarchicalMixer(GossipMixer):
    """FSDP-inside / gossip-across: psum-mean over ``replica_axis`` then gossip.

    Node-stacked leaves are *replicated* across ``replica_axis`` (each node's
    replicas hold divergent gradient contributions that are averaged here),
    then the per-node consensus step runs over ``node_axis``.
    """

    def __init__(self, decomp, mesh, node_axis, replica_axis: str,
                 param_specs):
        super().__init__(decomp, mesh, node_axis, param_specs)
        self.replica_axis = replica_axis
        self._r_size = mesh.shape[replica_axis]

    def _mix(self, theta):
        def body(t, sw, mws):
            # average the within-node replicas (plain DP all-reduce over ICI)
            t = jax.tree.map(
                lambda x: jax.lax.psum(x, self.replica_axis) / self._r_size, t
            )
            return gossip_mix_local(t, sw, mws, self.perms, self.axis)

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self.param_specs, self._p_node,
                      [self._p_node] * len(self.match_ws)),
            out_specs=self.param_specs,
        )(theta, self.self_w, list(self.match_ws))


def make_hierarchical_mixer(
    decomp: MixingDecomposition,
    mesh: jax.sharding.Mesh,
    node_axis: AxisName,
    replica_axis: str,
    param_specs,
    compression: CompressionConfig | None = None,
) -> Mixer:
    """Hierarchical replica-average + gossip (or its compressed counterpart)."""
    if _compression_enabled(compression):
        return CompressedGossipMixer(decomp, mesh, node_axis, param_specs,
                                     compression, replica_axis=replica_axis)
    return HierarchicalMixer(decomp, mesh, node_axis, replica_axis, param_specs)


class IdentityMixer(Mixer):
    """No communication — for ablations (pure local SGD)."""

    def _mix(self, theta):
        return theta

    def bytes_per_round(self, params) -> int:
        return 0


def make_identity_mixer() -> Mixer:
    return IdentityMixer()


class RepeatMixer(Mixer):
    """θ ← θ·W^rounds: multiple gossip rounds per optimizer step.

    Theorem 1's consensus term contracts like ρ^rounds, so m rounds on a
    sparse graph can substitute for a denser graph at m× the mixing wire —
    a knob for trading interconnect bytes against the convergence constant
    (see EXPERIMENTS.md §Perf A4 for the measured trade).
    """

    def __init__(self, mixer: Mixer, rounds: int):
        if rounds < 1:
            raise ValueError("rounds must be >= 1")
        self.inner = mixer
        self.rounds = rounds

    @property
    def compression(self):
        return self.inner.compression

    @property
    def traced_wire(self) -> bool:
        return self.inner.traced_wire

    def init_state(self, params):
        return self.inner.init_state(params)

    def state_specs(self, param_specs):
        return self.inner.state_specs(param_specs)

    def __call__(self, theta, state, *, round=None):
        total_bits = jnp.float32(0.0)
        for _ in range(self.rounds):
            theta, state = self.inner(theta, state, round=round)
            total_bits = total_bits + state.wire_bits
        # wire_bits is per-*step* accounting: sum the inner rounds
        return theta, state._replace(wire_bits=total_bits)

    def bytes_per_round(self, params) -> int:
        return self.rounds * self.inner.bytes_per_round(params)

    def wire_dtype_bytes(self, params):
        inner = self.inner.wire_dtype_bytes(params)
        if inner is None:
            return None
        # the python loop unrolls: the HLO carries `rounds` copies of the
        # inner round's collectives
        return {dt: self.rounds * b for dt, b in inner.items()}


def repeat_mixer(mixer: Mixer, rounds: int) -> Mixer:
    return RepeatMixer(mixer, rounds)
