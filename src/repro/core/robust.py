"""KL-regularized distributionally-robust objective (paper §4, Eq. 6-9).

The min-max problem  min_Θ max_{λ∈Δ} Σ λ_i f_i(Θ) − μ·KL(λ ‖ 1/K)  collapses,
after exact inner maximization, to  min_Θ (1/K) Σ_i exp(f_i(Θ)/μ)  (Eq. 8).

DR-DSGD realizes this with a per-node multiplicative factor on the local
stochastic gradient:  scale_i = h_i/μ = exp(ℓ̄_i/μ)/μ  (Alg. 2, line 3).
Assumption 4 (bounded loss) is enforced here with a configurable clip before
the exponent, per App. A.1's log(M) argument.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Configuration of the KL-DRO reweighting.

    Attributes:
      mu: regularization strength μ. μ→∞ recovers ERM/DSGD; smaller μ is more
        robust/fair. Theory (Corollary 1) covers μ ≥ 1; the paper's
        experiments use μ ∈ [2, 9].
      loss_clip: upper clip M on the scalar loss before exponentiation
        (Assumption 4 / App. A.1). None disables.
      enabled: False degrades the trainer to vanilla DSGD (the paper's
        baseline), keeping everything else identical.
    """

    mu: float = 6.0
    loss_clip: float | None = 10.0
    enabled: bool = True

    def __post_init__(self):
        if self.mu <= 0:
            raise ValueError(f"mu must be > 0, got {self.mu}")


def robust_scale(loss: jax.Array, cfg: RobustConfig) -> jax.Array:
    """Gradient scale h(θ;μ)/μ = exp(ℓ̄/μ)/μ for a (batch-mean) loss scalar.

    Works on any-shaped loss array (e.g. (K,) node losses) elementwise.
    With ``enabled=False`` returns ones (DSGD).
    """
    loss = loss.astype(jnp.float32)
    if not cfg.enabled:
        return jnp.ones_like(loss)
    ell = loss if cfg.loss_clip is None else jnp.minimum(loss, cfg.loss_clip)
    return jnp.exp(ell / cfg.mu) / cfg.mu


def robust_objective(node_losses: jax.Array, cfg: RobustConfig) -> jax.Array:
    """F(Θ) = (1/K) Σ exp(f_i/μ) (Eq. 8) — the quantity DR-DSGD descends.

    For reporting we return μ·log F, i.e. the soft-max of node losses (Eq. 7),
    which is in loss units and → mean(losses) as μ→∞.
    """
    ell = node_losses.astype(jnp.float32)
    if cfg.loss_clip is not None:
        ell = jnp.minimum(ell, cfg.loss_clip)
    if not cfg.enabled:
        return jnp.mean(ell)
    # centered logsumexp: μ log (1/K Σ e^{ℓ/μ}) computed around mean(ℓ) so
    # large μ does not lose the signal to fp32 cancellation
    mean = jnp.mean(ell)
    return mean + cfg.mu * (
        jax.nn.logsumexp((ell - mean) / cfg.mu) - jnp.log(ell.shape[-1])
    )


def mixture_weights(node_losses: jax.Array, cfg: RobustConfig) -> jax.Array:
    """The implied adversarial mixture λ*_i ∝ exp(f_i/μ) (Eq. 4-6 dual).

    Useful for logging which nodes the robust objective is focusing on.
    """
    ell = node_losses.astype(jnp.float32)
    if cfg.loss_clip is not None:
        ell = jnp.minimum(ell, cfg.loss_clip)
    if not cfg.enabled:
        return jnp.full_like(ell, 1.0 / ell.shape[-1])
    return jax.nn.softmax(ell / cfg.mu, axis=-1)
