"""High-level DecentralizedTrainer: graph + mixer + step, one object.

This is the public API used by the examples and benchmarks:

    trainer = DecentralizedTrainer(
        loss_fn, predict_fn, num_nodes=10,
        graph="erdos_renyi", graph_kwargs={"p": 0.3},
        robust=RobustConfig(mu=6.0), lr=0.05)
    state = trainer.init(params_single)
    state, metrics = trainer.step(state, batch)      # one jitted step
    state, ms = trainer.run(state, batches)          # scan-compiled multi-step
    accs = trainer.eval_per_node(state, x_test, y_test)

``run`` is the hot-loop driver: it folds N train steps into ONE compiled
``jax.lax.scan`` program with the carried state donated, so the per-step
Python dispatch overhead of the ``step`` loop disappears (see EXPERIMENTS.md
§Run-driver for measured steps/s).  ``batches`` is the step-loop batch pytree
stacked along a leading time axis; metrics come back stacked the same way.
Declarative construction (CLI flags, benchmarks, examples) goes through
:class:`repro.core.spec.TrainerSpec` → ``spec.build(loss_fn, ...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CompressionConfig
from repro.comm.protocol import Mixer
from repro.core.consensus import make_dense_mixer, make_identity_mixer
from repro.core.drdsgd import (
    DecentralizedState,
    TrainStepConfig,
    build_eval_step,
    build_train_step,
    init_state,
    replicate_params,
)
from repro.core.robust import RobustConfig
from repro.graphs import build_graph, metropolis_weights, spectral_norm
from repro.obs.profiler import PhaseTimer
from repro.optim import Optimizer, sgd


def run_segments(trainer: "DecentralizedTrainer", state, sample_batch,
                 steps: int, seg: int, on_segment=None, *, obs=None):
    """Drive ``trainer.run`` in host-sampled logging segments.

    For data pipelines that sample batches host-side per step
    (``sample_batch(step) -> batch pytree`` of numpy/array leaves): batches
    are stacked ``seg`` at a time, so device memory holds at most one
    segment while the scan driver amortizes dispatch across it.
    ``on_segment(last_step, state, seg_metrics)`` runs between compiled
    segments (the epoch-level host hook; same retention caveat as
    ``run`` — eval the state inside the hook, don't keep it).

    ``obs`` (a :class:`repro.obs.MetricsSink`) adds the phase-timer rollup:
    every chunk emits one ``perf`` record (steps/s, wire bytes/s, wall-clock
    per ``sample``/``run``/``hook`` phase) into the telemetry stream, and
    the ``run`` phase blocks on the segment's results so the timings are
    wall-clock honest (one host sync per *segment* — the per-step taps stay
    async).
    """
    timer = PhaseTimer() if obs is not None else None
    done = 0
    while done < steps:
        n = min(seg, steps - done)
        if timer is None:
            stacked = jax.tree.map(
                lambda *xs: jnp.asarray(np.stack(xs)),
                *[sample_batch(done + i) for i in range(n)])
            state, ms = trainer.run(state, stacked)
            done += n
            if on_segment is not None:
                on_segment(done - 1, state, ms)
            continue
        with timer.phase("sample"):
            stacked = jax.tree.map(
                lambda *xs: jnp.asarray(np.stack(xs)),
                *[sample_batch(done + i) for i in range(n)])
        with timer.phase("run"):
            state, ms = trainer.run(state, stacked)
            jax.block_until_ready(ms)
        done += n
        if on_segment is not None:
            with timer.phase("hook"):
                on_segment(done - 1, state, ms)
        wire = (float(jnp.sum(ms["comm_bytes"]))
                if "comm_bytes" in ms else None)
        obs.log("perf", done - 1,
                **timer.rollup(steps=n, wire_bytes=wire))
        timer.reset()
    return state


@dataclasses.dataclass
class DecentralizedTrainer:
    """Decentralized (DR-)DSGD trainer over a communication graph."""

    loss_fn: Callable[[Any, Any], jax.Array]
    predict_fn: Callable[[Any, Any], jax.Array] | None = None
    num_nodes: int = 10
    graph: str = "erdos_renyi"
    graph_kwargs: dict = dataclasses.field(default_factory=dict)
    robust: RobustConfig = dataclasses.field(default_factory=RobustConfig)
    optimizer: Optimizer | None = None
    lr: float = 0.05
    grad_clip: float | None = None
    mixer: Mixer | None = None            # override (e.g. gossip mixer on a mesh)
    mixing: str = "metropolis"            # or "max_degree", "none"
    compression: CompressionConfig | None = None
                                          # wire codec for the consensus step
                                          # (repro.comm); None = full precision
    dynamics: Any = None                  # repro.dynamics.DynamicsConfig:
                                          # time-varying topology / faults /
                                          # local updates; None = static
                                          # synchronous consensus
    mix_every: int = 1                    # consensus period (local SGD when >1)
    metrics_disagreement: bool = True     # Lemma-3 discrepancy metric; costs an
                                          # extra cross-node reduction per step
    obs: Any = None                       # repro.obs.MetricsSink: stream the
                                          # per-step record (metrics + per-node
                                          # losses/DR weights) to the host via
                                          # an in-graph tap; None = no telemetry
    loss_has_aux: bool = False
    jit: bool = True
    sanitize: bool = False                # checkify-wrap the step with the
                                          # runtime invariant checks of
                                          # repro.analysis.sanitize; a failed
                                          # check raises on the host at the
                                          # next err.throw() (per step/run),
                                          # params stay bit-exact when off

    def __post_init__(self):
        g = build_graph(self.graph, self.num_nodes, **self.graph_kwargs)
        if not g.is_connected():
            raise ValueError("communication graph must be connected (Assumption 5)")
        self.graph_obj = g
        if self.mixing == "none":
            self.w = np.eye(self.num_nodes)
        elif self.mixing == "metropolis":
            self.w = metropolis_weights(g)
        elif self.mixing == "max_degree":
            from repro.graphs import max_degree_weights

            self.w = max_degree_weights(g)
        else:
            raise ValueError(f"unknown mixing {self.mixing!r}")
        self.rho = spectral_norm(self.w)
        dyn = self.dynamics if (self.dynamics is not None
                                and self.dynamics.enabled) else None
        if self.mixer is None:
            if dyn is not None and self.mixing != "none":
                # dynamic topology / faults / local updates: dense-lowering
                # stack from repro.dynamics (lazy import: dynamics builds on
                # repro.core.consensus)
                from repro.dynamics import build_dynamic_mixer

                self.mixer = build_dynamic_mixer(
                    dyn, self.w, compression=self.compression)
            else:
                self.mixer = (
                    make_identity_mixer() if self.mixing == "none"
                    else make_dense_mixer(self.w, compression=self.compression)
                )
        else:
            if dyn is not None:
                raise ValueError(
                    "both a pre-built mixer and a DynamicsConfig were "
                    "provided — wrap the mixer yourself (repro.dynamics."
                    "LocalUpdateMixer / DynamicGossipMixer) or drop one")
            if self.compression is not None and self.compression.enabled \
                    and self.mixer.compression is None:
                raise ValueError(
                    "compression is set but the provided mixer is "
                    "uncompressed; build the mixer with the same "
                    "CompressionConfig")
        if self.optimizer is None:
            self.optimizer = sgd(self.lr)
        step_cfg = TrainStepConfig(
            robust=self.robust, grad_clip=self.grad_clip,
            metrics_disagreement=self.metrics_disagreement,
            compression=self.compression, mix_every=self.mix_every)
        self._train_step_fn = build_train_step(
            self.loss_fn, self.optimizer, self.mixer, step_cfg,
            loss_has_aux=self.loss_has_aux, obs=self.obs,
            sanitize=self.sanitize,
        )
        if self.sanitize:
            # the step stages checkify.check calls: transform once, jit the
            # transformed fn, and surface failures host-side via err.throw()
            from jax.experimental import checkify

            checked_step = checkify.checkify(
                self._train_step_fn, errors=checkify.user_checks)
            jitted_step = (jax.jit(checked_step) if self.jit
                           else checked_step)

            def step_and_throw(state, batch):
                err, out = jitted_step(state, batch)
                err.throw()
                return out

            if self.jit:
                # keep the wrapper trackable by RecompileWatchdog
                step_and_throw._cache_size = jitted_step._cache_size
            self._train_step = step_and_throw
        else:
            self._train_step = (jax.jit(self._train_step_fn) if self.jit
                                else self._train_step_fn)

        if self.sanitize:
            from jax.experimental import checkify

            checked_body = checkify.checkify(
                self._train_step_fn, errors=checkify.user_checks)

            def scan_run(state, batches):
                # discharge checkify PER STEP inside the scan body: the
                # error reaching the mixer's shard_map is then always the
                # empty one (checkify's shard_map rule reshapes any live
                # error to per-device shape, which breaks the scan carry),
                # and the per-step errors ride out as a stacked scan output
                # for one batched throw() on the host
                def body(st, batch):
                    err, (st2, m) = checked_body(st, batch)
                    return st2, (err, m)

                state, (errs, ms) = jax.lax.scan(body, state, batches)
                return state, (errs, ms)
        else:

            def scan_run(state, batches):
                return jax.lax.scan(self._train_step_fn, state, batches)

        # the jittable scan driver, kept for the static auditor
        # (repro.analysis.audit probes donation on it even when the
        # err.throw() wrapping makes self._run a host-throwing closure)
        self._scan_run_fn = scan_run

        def eager_run(state, batches):
            # jit=False debugging path: plain Python loop so prints and
            # breakpoints inside loss_fn still fire (scan would trace them)
            t = jax.tree.leaves(batches)[0].shape[0]
            out = []
            for i in range(t):
                state, m = self._train_step(
                    state, jax.tree.map(lambda x: x[i], batches))
                out.append(m)
            return state, jax.tree.map(lambda *xs: jnp.stack(xs), *out)

        # the multi-step driver: one compiled program for N steps, with the
        # carried DecentralizedState donated (params/opt/comm buffers are
        # reused in place on backends that support donation)
        if self.sanitize and self.jit:
            checked_run = jax.jit(scan_run, donate_argnums=(0,))

            def run_and_throw(state, batches):
                state, (errs, ms) = checked_run(state, batches)
                errs.throw()  # batched over steps: reports every violation
                return state, ms

            # keep the wrapper trackable by RecompileWatchdog
            run_and_throw._cache_size = checked_run._cache_size
            self._run = run_and_throw
        elif self.jit:
            self._run = jax.jit(scan_run, donate_argnums=(0,))
        else:
            self._run = eager_run
        if self.predict_fn is not None:
            self._eval_step = build_eval_step(self.predict_fn)
            if self.jit:
                self._eval_step = jax.jit(self._eval_step)

    # -- public API ---------------------------------------------------------

    def init(self, params_single) -> DecentralizedState:
        """All nodes start at the same point (Lemma 3 precondition)."""
        node_params = replicate_params(params_single, self.num_nodes)
        return init_state(node_params, self.optimizer, mixer=self.mixer)

    def init_stacked(self, node_params) -> DecentralizedState:
        return init_state(node_params, self.optimizer, mixer=self.mixer)

    def step(self, state: DecentralizedState, batch):
        state, metrics = self._train_step(state, batch)
        return state, self._drain_tap(metrics)

    def _drain_tap(self, metrics):
        """Pop the batched-tap payload a segment returned and deliver its
        records to the sink — keeps the metrics tree callers see identical
        with the sink on or off (see ``MetricsSink.tap_drain``)."""
        if self.obs is None:
            return metrics
        return self.obs.tap_drain(metrics)

    def run(self, state: DecentralizedState, batches, *, steps: int | None = None,
            epoch_steps: int | None = None, on_epoch=None):
        """Run many train steps as one ``lax.scan`` program.

        Args:
          state: carried :class:`DecentralizedState` — DONATED to the
            compiled program; do not reuse the passed-in buffers afterwards
            (on CPU donation is a no-op, but portable callers should treat
            the argument as consumed).
          batches: the per-step batch pytree stacked along a new leading time
            axis, i.e. every leaf is (T, K, ...) where ``step`` takes
            (K, ...).  Build it host-side with ``np.stack``.
          steps: optional step count; defaults to the leading dim T of the
            stacked batches, and slices the batches when smaller.
          epoch_steps / on_epoch: host-callback hook for eval/logging —
            the scan is chopped into epochs of ``epoch_steps`` steps and
            ``on_epoch(epoch_index, state, epoch_metrics)`` runs as plain
            Python between the compiled segments (``epoch_metrics`` is the
            metrics dict of that segment, each leaf (epoch_steps,)).  Equal
            epochs reuse one compiled program; a ragged final epoch costs
            one extra compile.  The per-epoch ``state`` handed to the hook
            is donated into the NEXT segment: read/eval it inside the hook,
            but do not retain it (on donation backends its buffers are
            invalidated as soon as the next segment launches; copy leaves
            you need to keep).

        Returns:
          (final_state, metrics) with every metric stacked to (steps,).
        """
        leaves = jax.tree.leaves(batches)
        if not leaves:
            raise ValueError("run() needs a non-empty batches pytree")
        total = leaves[0].shape[0]
        if steps is None:
            steps = total
        elif steps > total:
            raise ValueError(f"steps={steps} > stacked batches T={total}")
        elif steps < total:
            batches = jax.tree.map(lambda x: x[:steps], batches)
        if on_epoch is None or epoch_steps is None or epoch_steps >= steps:
            state, metrics = self._run(state, batches)
            metrics = self._drain_tap(metrics)
            if on_epoch is not None:
                on_epoch(0, state, metrics)
            return state, metrics
        chunks = []
        for e, start in enumerate(range(0, steps, epoch_steps)):
            seg = jax.tree.map(
                lambda x: x[start:start + epoch_steps], batches)
            state, ms = self._run(state, seg)
            ms = self._drain_tap(ms)
            on_epoch(e, state, ms)
            chunks.append(ms)
        metrics = jax.tree.map(lambda *xs: jnp.concatenate(xs), *chunks)
        return state, metrics

    def eval_per_node(self, state: DecentralizedState, x, y) -> jax.Array:
        if self.predict_fn is None:
            raise ValueError("predict_fn not provided")
        return self._eval_step(state.params, jnp.asarray(x), jnp.asarray(y))

    def eval_local_distributions(self, state: DecentralizedState, x_nodes,
                                 y_nodes) -> dict:
        """Paper §6.2 protocol: device i's model on device i's distribution.

        x_nodes: (K, n, ...), y_nodes: (K, n). Worst distribution test
        accuracy = min_i acc(θ_i, D_i^test); fairness = STDEV across devices.
        """
        if self.predict_fn is None:
            raise ValueError("predict_fn not provided")

        def one(params_i, x_i, y_i):
            logits = self.predict_fn(params_i, x_i)
            return jnp.mean((jnp.argmax(logits, -1) == y_i).astype(jnp.float32))

        accs = np.asarray(jax.vmap(one)(
            state.params, jnp.asarray(x_nodes), jnp.asarray(y_nodes)))
        return {
            "acc_avg": float(accs.mean()),
            "acc_worst_dist": float(accs.min()),
            "acc_node_std": float(accs.std()),
            "acc_node_min": float(accs.min()),
            "acc_nodes": [float(a) for a in accs],
        }

    def eval_worst_distribution(self, state: DecentralizedState, per_class_sets
                                ) -> dict:
        """Paper's metrics: avg / worst-distribution accuracy + STDEV.

        ``per_class_sets`` is a list of (x, y) test subsets (one per class or
        per target distribution). Worst-distribution accuracy = min over
        subsets of the consensus-model accuracy; per-node stats use each
        node's own model on the full test set (paper Figs. 2-4).
        """
        kept = [(x, y) for x, y in per_class_sets if len(y)]
        if not kept:
            raise ValueError(
                "eval_worst_distribution needs at least one non-empty test "
                "subset; all per_class_sets entries are empty")
        accs = [float(jnp.mean(self.eval_per_node(state, x, y)))
                for x, y in kept]
        x_all = np.concatenate([x for x, _ in kept])
        y_all = np.concatenate([y for _, y in kept])
        node_accs = np.asarray(self.eval_per_node(state, x_all, y_all))
        return {
            "acc_avg": float(node_accs.mean()),
            "acc_worst_dist": float(min(accs)),
            "acc_node_std": float(node_accs.std()),
            "acc_node_min": float(node_accs.min()),
            "acc_nodes": [float(a) for a in node_accs],
        }
