"""High-level DecentralizedTrainer: graph + mixer + step, one object.

This is the public API used by the examples and benchmarks:

    trainer = DecentralizedTrainer(
        loss_fn, predict_fn, num_nodes=10,
        graph="erdos_renyi", graph_kwargs={"p": 0.3},
        robust=RobustConfig(mu=6.0), lr=0.05)
    state = trainer.init(params_single)
    state, metrics = trainer.step(state, batch)      # jitted
    accs = trainer.eval_per_node(state, x_test, y_test)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CompressionConfig
from repro.core.consensus import Mixer, make_dense_mixer, make_identity_mixer
from repro.core.drdsgd import (
    DecentralizedState,
    TrainStepConfig,
    build_eval_step,
    build_train_step,
    init_state,
    replicate_params,
)
from repro.core.robust import RobustConfig
from repro.graphs import build_graph, metropolis_weights, spectral_norm
from repro.optim import Optimizer, sgd


@dataclasses.dataclass
class DecentralizedTrainer:
    """Decentralized (DR-)DSGD trainer over a communication graph."""

    loss_fn: Callable[[Any, Any], jax.Array]
    predict_fn: Callable[[Any, Any], jax.Array] | None = None
    num_nodes: int = 10
    graph: str = "erdos_renyi"
    graph_kwargs: dict = dataclasses.field(default_factory=dict)
    robust: RobustConfig = dataclasses.field(default_factory=RobustConfig)
    optimizer: Optimizer | None = None
    lr: float = 0.05
    grad_clip: float | None = None
    mixer: Mixer | None = None            # override (e.g. gossip mixer on a mesh)
    mixing: str = "metropolis"            # or "max_degree", "none"
    compression: CompressionConfig | None = None
                                          # wire codec for the consensus step
                                          # (repro.comm); None = full precision
    mix_every: int = 1                    # consensus period (local SGD when >1)
    loss_has_aux: bool = False
    jit: bool = True

    def __post_init__(self):
        g = build_graph(self.graph, self.num_nodes, **self.graph_kwargs)
        if not g.is_connected():
            raise ValueError("communication graph must be connected (Assumption 5)")
        self.graph_obj = g
        if self.mixing == "none":
            self.w = np.eye(self.num_nodes)
        elif self.mixing == "metropolis":
            self.w = metropolis_weights(g)
        elif self.mixing == "max_degree":
            from repro.graphs import max_degree_weights

            self.w = max_degree_weights(g)
        else:
            raise ValueError(f"unknown mixing {self.mixing!r}")
        self.rho = spectral_norm(self.w)
        if self.mixer is None:
            self.mixer = (
                make_identity_mixer() if self.mixing == "none"
                else make_dense_mixer(self.w, compression=self.compression)
            )
        elif self.compression is not None and self.compression.enabled \
                and not getattr(self.mixer, "stateful", False):
            raise ValueError(
                "compression is set but the provided mixer is uncompressed; "
                "build the mixer with the same CompressionConfig")
        if self.optimizer is None:
            self.optimizer = sgd(self.lr)
        step_cfg = TrainStepConfig(robust=self.robust, grad_clip=self.grad_clip,
                                   compression=self.compression,
                                   mix_every=self.mix_every)
        self._train_step = build_train_step(
            self.loss_fn, self.optimizer, self.mixer, step_cfg,
            loss_has_aux=self.loss_has_aux,
        )
        if self.jit:
            self._train_step = jax.jit(self._train_step)
        if self.predict_fn is not None:
            self._eval_step = build_eval_step(self.predict_fn)
            if self.jit:
                self._eval_step = jax.jit(self._eval_step)

    # -- public API ---------------------------------------------------------

    def init(self, params_single) -> DecentralizedState:
        """All nodes start at the same point (Lemma 3 precondition)."""
        node_params = replicate_params(params_single, self.num_nodes)
        return init_state(node_params, self.optimizer, mixer=self.mixer)

    def init_stacked(self, node_params) -> DecentralizedState:
        return init_state(node_params, self.optimizer, mixer=self.mixer)

    def step(self, state: DecentralizedState, batch):
        return self._train_step(state, batch)

    def eval_per_node(self, state: DecentralizedState, x, y) -> jax.Array:
        if self.predict_fn is None:
            raise ValueError("predict_fn not provided")
        return self._eval_step(state.params, jnp.asarray(x), jnp.asarray(y))

    def eval_local_distributions(self, state: DecentralizedState, x_nodes,
                                 y_nodes) -> dict[str, float]:
        """Paper §6.2 protocol: device i's model on device i's distribution.

        x_nodes: (K, n, ...), y_nodes: (K, n). Worst distribution test
        accuracy = min_i acc(θ_i, D_i^test); fairness = STDEV across devices.
        """
        if self.predict_fn is None:
            raise ValueError("predict_fn not provided")

        def one(params_i, x_i, y_i):
            logits = self.predict_fn(params_i, x_i)
            return jnp.mean((jnp.argmax(logits, -1) == y_i).astype(jnp.float32))

        accs = np.asarray(jax.vmap(one)(
            state.params, jnp.asarray(x_nodes), jnp.asarray(y_nodes)))
        return {
            "acc_avg": float(accs.mean()),
            "acc_worst_dist": float(accs.min()),
            "acc_node_std": float(accs.std()),
            "acc_node_min": float(accs.min()),
        }

    def eval_worst_distribution(self, state: DecentralizedState, per_class_sets
                                ) -> dict[str, float]:
        """Paper's metrics: avg / worst-distribution accuracy + STDEV.

        ``per_class_sets`` is a list of (x, y) test subsets (one per class or
        per target distribution). Worst-distribution accuracy = min over
        subsets of the consensus-model accuracy; per-node stats use each
        node's own model on the full test set (paper Figs. 2-4).
        """
        accs = []
        for x, y in per_class_sets:
            if len(y) == 0:
                continue
            accs.append(float(jnp.mean(self.eval_per_node(state, x, y))))
        x_all = np.concatenate([x for x, y in per_class_sets if len(y)])
        y_all = np.concatenate([y for x, y in per_class_sets if len(y)])
        node_accs = np.asarray(self.eval_per_node(state, x_all, y_all))
        return {
            "acc_avg": float(node_accs.mean()),
            "acc_worst_dist": float(min(accs)),
            "acc_node_std": float(node_accs.std()),
            "acc_node_min": float(node_accs.min()),
        }
