"""DR-DSGD / DSGD decentralized train-step builders (paper Alg. 1 & 2).

The train step operates on a :class:`DecentralizedState` whose params pytree is
*node-stacked*: every leaf has leading axis K.  One step is:

  1. per-node minibatch gradient  g_i  and minibatch loss  ℓ̄_i   (vmap over K)
  2. robust scale   s_i = exp(ℓ̄_i/μ)/μ     (DR-DSGD; s_i = 1 for DSGD)
  3. local update   θ_i⁺ = opt(θ_i, s_i·g_i)
  4. consensus      θ, comm ← mix(θ⁺, comm, round=step)

Step 4 is the uniform Mixer protocol (``repro.comm.protocol``): every mixer
— identity, dense, gossip, hierarchical, compressed, repeated — threads one
``CommState`` through ``DecentralizedState.comm``, so there is exactly one
consensus code path regardless of the wire codec.

Distribution: under pjit the node axis is sharded over the mesh's data axes,
so step 1-3 are embarrassingly parallel and step 4 is the only communication
(this is the paper's communication pattern, made explicit for XLA).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import CompressionConfig
from repro.comm.protocol import CommState, Mixer, trivial_comm_state
from repro.core.robust import RobustConfig, mixture_weights, robust_objective, robust_scale
from repro.obs.hist import TRAIN_HISTOGRAMS, HistSpec, hist_counts
from repro.obs.profiler import scope
from repro.optim.optimizers import Optimizer
from repro.utils.tree import tree_node_disagreement

LossFn = Callable[[Any, Any], jax.Array]  # (params, batch) -> scalar loss


class DecentralizedState(NamedTuple):
    params: Any          # node-stacked pytree, leading axis K
    opt_state: Any
    step: jax.Array      # scalar int32
    comm: Any = ()       # the mixer's CommState (trivial for uncompressed)

    @property
    def ef_state(self):
        """Pre-v2 alias for :attr:`comm` (the CommState of the mixer)."""
        return self.comm


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    robust: RobustConfig
    grad_clip: float | None = None        # per-node global-norm clip (pre-scale)
    metrics_disagreement: bool = True     # Lemma-3 discrepancy metric (extra comm)
    mix_every: int = 1                    # consensus period: 1 = DSGD/DR-DSGD;
                                          # >1 + complete graph = FedAvg-style
                                          # local SGD with periodic averaging
    compression: CompressionConfig | None = None
                                          # wire codec the mixer was built
                                          # with (repro.comm); recorded here
                                          # so the step can sanity-check the
                                          # mixer
    histograms: tuple[HistSpec, ...] = TRAIN_HISTOGRAMS
                                          # in-jit streaming histograms
                                          # (repro.obs.hist) joining the
                                          # tap's decimated vector payload;
                                          # only computed when obs is given


def init_state(node_params, optimizer: Optimizer,
               mixer: Mixer | None = None) -> DecentralizedState:
    """Build state from node-stacked params (see utils.tree.tree_stack_nodes).

    Pass the mixer so its ``CommState`` is allocated into ``comm``; without
    one the trivial state is used (correct for any uncompressed mixer).
    """
    comm = mixer.init_state(node_params) if mixer is not None \
        else trivial_comm_state()
    return DecentralizedState(
        params=node_params,
        opt_state=optimizer.init(node_params),
        step=jnp.zeros((), jnp.int32),
        comm=comm,
    )


def replicate_params(params, k: int):
    """Broadcast a single param pytree to K identical node replicas.

    The theory (Lemma 3) assumes all local models start at the same point.
    """
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), params
    )


def build_train_step(
    loss_fn: LossFn,
    optimizer: Optimizer,
    mixer: Mixer,
    cfg: TrainStepConfig,
    loss_has_aux: bool = False,
    obs=None,
    sanitize: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` is a pytree whose leaves carry a leading node axis K, matching
    the params' node axis.  ``loss_fn(params_i, batch_i)`` must return a
    scalar (or (scalar, aux-dict) with ``loss_has_aux``).

    ``obs`` is an optional :class:`repro.obs.MetricsSink`: when given, every
    step packs its record — the scalar metrics plus the per-node vectors
    (``loss_nodes``, ``dr_weights``) and the in-jit histogram counts
    (``cfg.histograms``, :mod:`repro.obs.hist`) — into flat f32 payload
    leaves (``obs.tap_pack``) merged into the returned metrics dict, where
    ``lax.scan`` stacks them for free: ZERO host callbacks in the compiled
    program.  ``trainer.run`` drains the payload per segment
    (``obs.tap_drain``), decimating the vector fields to every
    ``obs.vector_every``-th record.  The tap only reads values the step
    computes anyway and the payload leaves are popped before metrics reach
    the caller, so the visible metrics tree, the scan carry's donation, and
    the trajectory stay bit-exact vs ``obs=None``.

    ``sanitize`` stages the runtime invariant checks of
    ``repro.analysis.sanitize`` (doubly-stochastic W, CHOCO cache drift,
    finite mixed params, in-container codec rate) after the consensus.
    They are ``checkify.check`` calls: the returned step must then run
    under a ``checkify.checkify`` transform (the trainer wraps it), and
    the computed values are untouched — the trajectory stays bit-exact vs
    ``sanitize=False``.
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=loss_has_aux)
    step_checks = None
    if sanitize:
        from repro.analysis.sanitize import step_checks
    if cfg.compression is not None and cfg.compression.enabled \
            and mixer.compression is None:
        raise ValueError(
            "TrainStepConfig.compression is set but the mixer is "
            "uncompressed — build it with the same CompressionConfig "
            "(see repro.core.consensus factories)")
    if cfg.mix_every > 1 and getattr(mixer, "period", 1) > 1:
        raise ValueError(
            "mix_every > 1 with a LocalUpdateMixer (period > 1) runs two "
            "consensus clocks against each other — express the local-update "
            "period in ONE place (the mixer's period is the dynamics-aware "
            "spelling: it keeps CommState.rounds ticking every step)")
    # scheduled codecs move the rate every round, so the static estimate is
    # wrong for them: report the mixer's traced per-round wire_bits instead
    # (and skip computing the dead static estimate entirely)
    traced_wire = mixer.traced_wire
    # straggler-skips-compute: replay the mixer's node-up vector to zero the
    # robust gradient scale of down nodes (FaultConfig.straggler_skips_compute;
    # the fault process is a pure function of CommState.rounds, so the mask
    # matches the consensus round's link failures exactly).  Unwrap stacking
    # wrappers (LocalUpdateMixer/RepeatMixer) to find the faulted mixer.
    _m, step_faults = mixer, None
    while _m is not None and step_faults is None:
        step_faults = getattr(_m, "faults", None)
        _m = getattr(_m, "inner", None)
    if not (step_faults is not None and step_faults.enabled
            and step_faults.straggler_skips_compute
            and (step_faults.straggler_p > 0 or step_faults.outage_p > 0)):
        step_faults = None

    def per_node(params_i, batch_i):
        if loss_has_aux:
            (loss, aux), grads = grad_fn(params_i, batch_i)
        else:
            loss, grads = grad_fn(params_i, batch_i)
            aux = {}
        if cfg.grad_clip is not None:
            from repro.optim.optimizers import clip_by_global_norm

            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        return loss, grads, aux

    def train_step(state: DecentralizedState, batch):
        if not isinstance(state.comm, CommState):
            raise ValueError(
                "DecentralizedState.comm must be the mixer's CommState — "
                "build the state with init_state(params, optimizer, "
                "mixer=mixer) (protocol v2: every mixer, compressed or "
                "not, carries one)")
        with scope("obs:grad"):
            losses, grads, aux = jax.vmap(per_node)(state.params, batch)
        # --- the paper's technique: exponential per-node gradient reweighting
        with scope("obs:dr_weighting"):
            scale = robust_scale(losses, cfg.robust)  # (K,)
            lam = mixture_weights(losses, cfg.robust)  # (K,) adversarial λ*
            if step_faults is not None:
                from repro.dynamics.faults import fault_keep_matrix

                # pre-increment clock: the same round index the mixer's
                # fault replay will consume this step
                _, up = fault_keep_matrix(
                    step_faults, state.comm.rounds, losses.shape[0])
                scale = scale * up
            scaled_grads = jax.tree.map(
                lambda g: g * scale.reshape((-1,) + (1,) * (g.ndim - 1)).astype(g.dtype),
                grads,
            )
        # --- local optimizer step (plain SGD in the paper)
        with scope("obs:local_update"):
            updated, opt_state = optimizer.update(
                scaled_grads, state.opt_state, state.params, state.step
            )
        # --- consensus: the only cross-node communication of the algorithm.
        # One protocol for every mixer; mix_every > 1 skips communication on
        # off-steps (local SGD / periodic averaging, the FedAvg-style PS
        # baseline of paper §1-2) and passes CommState through untouched.
        is_mix_step = state.step % cfg.mix_every == cfg.mix_every - 1
        with scope("obs:consensus"):
            if cfg.mix_every == 1:
                mixed, comm = mixer(updated, state.comm, round=state.step)
            else:
                mixed, comm = jax.lax.cond(
                    is_mix_step,
                    lambda theta, cs: mixer(theta, cs, round=state.step),
                    lambda theta, cs: (theta, cs),
                    updated, state.comm)
        if step_checks is not None:
            with scope("obs:sanitize"):
                step_checks(mixer, state.comm, mixed, comm)
        # estimated wire bytes this step (static estimate, gated on mixing;
        # traced wire_bits/8 when a schedule makes the rate dynamic)
        if traced_wire:
            comm_bytes = jnp.where(is_mix_step, comm.wire_bits / 8.0, 0.0)
        else:
            # bytes_per_round is shape-only host math on static mixers
            # (traced_wire is False here): no tracer reaches the float()
            round_bytes = float(mixer.bytes_per_round(state.params))  # repro: noqa[RPR002]
            if cfg.mix_every == 1:
                comm_bytes = jnp.float32(round_bytes)
            else:
                comm_bytes = jnp.where(is_mix_step, round_bytes, 0.0)
        cm = comm.metrics
        metrics = {
            "comm_bytes": comm_bytes,
            "loss_mean": jnp.mean(losses),
            "loss_worst": jnp.max(losses),
            "loss_std": jnp.std(losses),
            "robust_objective": robust_objective(losses, cfg.robust),
            "scale_mean": jnp.mean(scale),
            "scale_max": jnp.max(scale),
            "lambda_max": jnp.max(lam),
            # wire_bits is "bits injected by the last round" — gate on the
            # mix predicate so off-steps (mix_every > 1) report 0, not the
            # stale value the lax.cond pass-through branch carries
            "wire_bits": jnp.where(is_mix_step, cm.wire_bits, 0.0),
            "ef_residual_norm": cm.res_norm,
        }
        if cfg.metrics_disagreement:
            metrics["disagreement"] = tree_node_disagreement(mixed)
        for k, v in aux.items():
            metrics[f"aux_{k}"] = jnp.mean(v)
        if obs is not None:
            # pack the step's record for the host sink.  The per-node
            # vectors (the paper's trajectory axes) and the in-jit histogram
            # counts ride only on the tap payload — decimated to every
            # obs.vector_every-th step at drain — not in the named metrics,
            # so the visible metrics tree is identical with the sink on or
            # off.  The payload leaves ride the scan's stacked outputs (no
            # host callback); trainer.run drains them when a segment returns.
            with scope("obs:tap"):
                rec = dict(metrics)
                # EF wire bookkeeping for host-side event derivation
                # (re-base firings / drift), when the mixer carries it
                for name in ("ef_rounds", "ef_drift"):
                    v = getattr(comm, name, ())
                    if hasattr(v, "dtype"):
                        rec[name] = v
                vectors = {
                    "loss_nodes": losses.astype(jnp.float32),
                    "dr_weights": lam,
                }
                hist_sources = {
                    "loss_nodes": losses,
                    "dr_weights": lam,
                    "ef_res": cm.res_norm,
                }
                for spec in cfg.histograms:
                    src = hist_sources.get(spec.source)
                    if src is not None:
                        vectors[spec.field] = hist_counts(src, spec)
                metrics = dict(metrics)
                metrics.update(obs.tap_pack(state.step, rec,
                                            vectors=vectors))
        return (
            DecentralizedState(mixed, opt_state, state.step + 1, comm),
            metrics,
        )

    return train_step


def build_eval_step(predict_fn: Callable[[Any, Any], jax.Array]):
    """Returns eval_step(node_params, x, y) -> (K,) per-node accuracies.

    Every node evaluates the *same* test inputs — matching the paper's
    protocol of reporting each device's test accuracy on the global test set
    (worst distribution accuracy = min over per-class/per-node accuracies).
    """

    def eval_step(node_params, x, y):
        def one(params_i):
            logits = predict_fn(params_i, x)
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

        return jax.vmap(one)(node_params)

    return eval_step
