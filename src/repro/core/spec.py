"""Declarative trainer construction: one spec shared by CLI, benchmarks, examples.

Before v2 every entry point hand-rolled its own argparse → constructor
translation (``launch/train.py``, ``launch/dryrun.py``, ``benchmarks/common``,
the examples).  :class:`TrainerSpec` is the single declarative description of
a decentralized training setup — graph, robustness, optimizer, consensus
wire codec and schedule — with three ways in:

    spec = TrainerSpec(num_nodes=8, graph="ring", mu=3.0, compress="int8")
    trainer = spec.build(loss_fn, predict_fn)

    ap = argparse.ArgumentParser()
    TrainerSpec.add_cli_args(ap)                      # the standard flags
    spec = TrainerSpec.from_args(ap.parse_args(), lr=0.1)

The compression-only helpers (:func:`add_compression_cli_args`,
:func:`compression_from_args`) are shared with entry points that build raw
mixers instead of a trainer (``launch/dryrun.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.comm import CompressionConfig, ScheduleConfig
from repro.comm.protocol import Mixer
from repro.core.api import DecentralizedTrainer
from repro.core.robust import RobustConfig

from repro.dynamics.config import TOPOLOGY_KINDS as _TOPOLOGY_CHOICES

_GRAPH_CHOICES = ("ring", "grid", "torus", "erdos_renyi", "geometric",
                  "complete", "star", "hypercube")
_COMPRESS_CHOICES = ("none", "bf16", "int8", "int4", "topk", "randk")
_SCHEDULE_CHOICES = ("none", "constant", "linear", "adaptive")


def add_dynamics_cli_args(ap) -> None:
    """Install the dynamic-graph / fault / local-update flags
    (``repro.dynamics``) on an argparse parser."""
    ap.add_argument("--topology", default="static", choices=_TOPOLOGY_CHOICES,
                    help="per-round topology process: static graph, "
                         "round-robin matchings, Bernoulli link dropout, "
                         "per-round geometric re-draws (repro.dynamics), or "
                         "hub — federated server averaging (FedAvg with "
                         "--local-updates; SCAFFOLD with --gradient-tracking)")
    ap.add_argument("--drop-p", type=float, default=0.0,
                    help="link dropout probability for --topology dropout")
    ap.add_argument("--radius", type=float, default=0.5,
                    help="connection radius for --topology geometric")
    ap.add_argument("--local-updates", type=int, default=1,
                    help="H: optimizer steps per consensus round "
                         "(local SGD between mixes when > 1)")
    ap.add_argument("--gradient-tracking", action="store_true",
                    help="carry the local-update drift correction "
                         "(2x consensus wire; uncompressed mixers only)")
    ap.add_argument("--ef-rebase-every", type=int, default=8,
                    help="B: re-base period of the error-feedback "
                         "compressed gossip wire over a time-varying "
                         "topology — every B-th consensus round exchanges "
                         "full-precision public copies to rebuild the "
                         "hat_mix cache (0 = never; static schedules only)")
    ap.add_argument("--ef-rebase-threshold", type=float, default=0.0,
                    help="adaptive re-base: measure the EF cache drift "
                         "||s - W_r theta_hat||_F each round and re-base "
                         "when it exceeds this threshold instead of on the "
                         "B clock (0 = clock)")
    ap.add_argument("--straggler-p", type=float, default=0.0,
                    help="per-node per-round probability of skipping "
                         "communication")
    ap.add_argument("--outage-p", type=float, default=0.0,
                    help="per-window probability a node is down for a whole "
                         "outage window (correlated faults)")
    ap.add_argument("--outage-len", type=int, default=10,
                    help="rounds per outage window")
    ap.add_argument("--straggler-skips-compute", action="store_true",
                    help="down nodes (stragglers/outages) lose their "
                         "gradient too: the robust per-node scale is masked "
                         "with the round's up vector, modeling preempted "
                         "compute instead of slow links")


def add_obs_cli_args(ap) -> None:
    """Install the observability flags (``repro.obs``) on an argparse parser.

    ``--log-every`` is deliberately not here: entry points own their logging
    cadence (it doubles as the ``run_segments`` chunk length).
    """
    ap.add_argument("--log-dir", default=None,
                    help="write schema-versioned JSONL telemetry "
                         "(repro.obs.MetricsSink: per-step train records, "
                         "eval fairness metrics, per-chunk perf rollups) "
                         "into this directory")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in jax.profiler.trace and dump a "
                         "perfetto trace under --log-dir (phases carry "
                         "obs:... scope names)")
    ap.add_argument("--tap-vectors-every", type=int, default=8,
                    help="decimation of the tap's vector payload: per-node "
                         "losses / DR weights / histogram counts land on "
                         "every N-th train record (scalars land every "
                         "step; 1 = vectors every step)")


def add_compression_cli_args(ap) -> None:
    """Install the standard consensus wire-codec flags on an argparse parser."""
    ap.add_argument("--compress", default="none", choices=_COMPRESS_CHOICES,
                    help="consensus wire codec (repro.comm)")
    ap.add_argument("--compress-ratio", type=float, default=0.01,
                    help="kept fraction for topk/randk")
    ap.add_argument("--compress-schedule", default="none",
                    choices=_SCHEDULE_CHOICES,
                    help="adapt the codec rate during training "
                         "(repro.comm.schedule): int8->int4 / annealed "
                         "topk ratio, driven by rounds (linear) or the "
                         "error-feedback innovation norm (adaptive)")
    ap.add_argument("--schedule-threshold", type=float, default=0.5,
                    help="adaptive: innovation-norm fraction below which "
                         "the rate anneals")
    ap.add_argument("--schedule-warmup", type=int, default=10,
                    help="adaptive: full-rate rounds before the reference "
                         "norm is latched")
    ap.add_argument("--schedule-rounds", type=int, default=300,
                    help="linear: rounds to anneal full -> aggressive rate")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="ablation: memoryless compression (stalls at the "
                         "quantization noise floor)")


def compression_from_args(args, seed: int = 0) -> CompressionConfig | None:
    """Build the CompressionConfig described by :func:`add_compression_cli_args`.

    Thin CLI wrapper over :meth:`TrainerSpec.compression_config` (SystemExit
    instead of ValueError for flag misuse).
    """
    spec = TrainerSpec(
        compress=args.compress,
        compress_ratio=args.compress_ratio,
        error_feedback=not args.no_error_feedback,
        compress_schedule=args.compress_schedule,
        schedule_threshold=args.schedule_threshold,
        schedule_warmup=args.schedule_warmup,
        schedule_rounds=args.schedule_rounds,
        seed=getattr(args, "seed", seed),
    )
    try:
        return spec.compression_config()
    except ValueError as e:
        raise SystemExit(
            "--compress-schedule needs a codec: pass --compress "
            "int8|int4|topk|randk") from e


@dataclasses.dataclass
class TrainerSpec:
    """Everything needed to build a :class:`DecentralizedTrainer`, declaratively.

    ``build(loss_fn, predict_fn)`` supplies the only non-declarative pieces
    (the task's loss/predict functions, or a pre-built mixer override).
    """

    num_nodes: int = 10
    graph: str = "erdos_renyi"
    graph_kwargs: dict = dataclasses.field(default_factory=dict)
    mixing: str = "metropolis"
    mu: float = 6.0
    robust: bool = True
    lr: float = 0.05
    grad_clip: float | None = None
    mix_every: int = 1
    metrics_disagreement: bool = True
    compress: str | CompressionConfig | None = "none"  # codec kind, or a
                                                       # pre-built config
    compress_ratio: float = 0.01
    error_feedback: bool = True
    compress_schedule: str = "none"
    schedule_threshold: float = 0.5
    schedule_warmup: int = 10
    schedule_rounds: int = 300
    topology: str = "static"              # per-round topology process
    drop_p: float = 0.0                   # link dropout for topology=dropout
    radius: float = 0.5                   # radius for topology=geometric
    local_updates: int = 1                # H: steps per consensus round
    gradient_tracking: bool = False       # local-update drift correction
    ef_rebase_every: int = 8              # B: EF-gossip hat_mix re-base period
    ef_rebase_threshold: float = 0.0      # adaptive re-base drift threshold
    straggler_p: float = 0.0              # per-round node comm skips
    outage_p: float = 0.0                 # correlated node outages
    outage_len: int = 10
    straggler_skips_compute: bool = False  # down nodes lose their gradient too
    seed: int = 0
    jit: bool = True
    sanitize: bool = False                # checkify invariant checks in-step

    # -- derived configs ----------------------------------------------------

    def robust_config(self) -> RobustConfig:
        return RobustConfig(mu=self.mu, enabled=self.robust)

    def dynamics_config(self):
        """The :class:`repro.dynamics.DynamicsConfig` this spec describes,
        or None for today's static synchronous setup."""
        from repro.dynamics import DynamicsConfig, FaultConfig

        faults = None
        if self.straggler_p > 0 or self.outage_p > 0:
            faults = FaultConfig(
                straggler_p=self.straggler_p, outage_p=self.outage_p,
                outage_len=self.outage_len, seed=self.seed,
                straggler_skips_compute=self.straggler_skips_compute)
        cfg = DynamicsConfig(
            topology=self.topology, drop_p=self.drop_p, radius=self.radius,
            local_updates=self.local_updates,
            gradient_tracking=self.gradient_tracking,
            ef_rebase_every=self.ef_rebase_every,
            ef_rebase_threshold=self.ef_rebase_threshold,
            faults=faults, seed=self.seed)
        return cfg if cfg.enabled else None

    def compression_config(self) -> CompressionConfig | None:
        if isinstance(self.compress, CompressionConfig):
            # a pre-built config passes through (benchmarks hand these in)
            return self.compress if self.compress.enabled else None
        if self.compress is None or self.compress == "none":
            if self.compress_schedule != "none":
                raise ValueError("compress_schedule needs a codec "
                                 "(compress='int8'|'int4'|'topk'|'randk')")
            return None
        schedule = None
        if self.compress_schedule != "none":
            schedule = ScheduleConfig(
                kind=self.compress_schedule,
                threshold=self.schedule_threshold,
                warmup_rounds=self.schedule_warmup,
                anneal_rounds=self.schedule_rounds,
            )
        return CompressionConfig(
            kind=self.compress, ratio=self.compress_ratio,
            error_feedback=self.error_feedback, seed=self.seed,
            schedule=schedule,
        )

    # -- the builder ---------------------------------------------------------

    def build(self, loss_fn, predict_fn=None, *, mixer: Mixer | None = None,
              optimizer=None, loss_has_aux: bool = False, obs=None
              ) -> DecentralizedTrainer:
        return DecentralizedTrainer(
            loss_fn,
            predict_fn=predict_fn,
            num_nodes=self.num_nodes,
            graph=self.graph,
            graph_kwargs=dict(self.graph_kwargs),
            robust=self.robust_config(),
            optimizer=optimizer,
            lr=self.lr,
            grad_clip=self.grad_clip,
            mixer=mixer,
            mixing=self.mixing,
            compression=self.compression_config(),
            dynamics=self.dynamics_config(),
            mix_every=self.mix_every,
            metrics_disagreement=self.metrics_disagreement,
            obs=obs,
            loss_has_aux=loss_has_aux,
            jit=self.jit,
            sanitize=self.sanitize,
        )

    # -- CLI integration ------------------------------------------------------

    @staticmethod
    def add_cli_args(ap) -> None:
        """Install the standard trainer flags (superset: includes compression).

        ``--nodes``/``--graph``/``--lr`` default to None so entry points can
        supply task-specific fallbacks via ``from_args(..., overrides)``.
        """
        ap.add_argument("--nodes", type=int, default=None)
        ap.add_argument("--graph", default=None, choices=_GRAPH_CHOICES)
        ap.add_argument("--p", type=float, default=0.3,
                        help="edge probability for erdos_renyi graphs")
        ap.add_argument("--mu", type=float, default=6.0)
        ap.add_argument("--dsgd", action="store_true",
                        help="disable DR (baseline)")
        ap.add_argument("--mix-every", type=int, default=1,
                        help="consensus period (local SGD when > 1)")
        ap.add_argument("--lr", type=float, default=None)
        ap.add_argument("--seed", type=int, default=0)
        ap.add_argument("--sanitize", action="store_true",
                        help="checkify-wrap the train step with runtime "
                             "invariant checks (doubly-stochastic W, CHOCO "
                             "cache drift, finite dequantized payloads, "
                             "in-range codec rate; repro.analysis.sanitize)")
        add_compression_cli_args(ap)
        add_dynamics_cli_args(ap)

    @classmethod
    def from_args(cls, args, **overrides: Any) -> "TrainerSpec":
        """Build a spec from an argparse namespace made by :meth:`add_cli_args`.

        Precedence: for ``--nodes``/``--lr``/``--graph`` (argparse default
        None) the CLI value wins when passed, otherwise the ``overrides``
        fallback applies.  Every other flag has a concrete argparse default
        and is copied from ``args`` unconditionally — ``overrides`` for
        those keys (``mu``, ``compress``, ...) have no effect; use them for
        fields without a flag (``grad_clip``, ``graph_kwargs``,
        ``metrics_disagreement``, ...).
        """
        spec = dict(overrides)
        spec.update(
            mu=args.mu,
            robust=not args.dsgd,
            mix_every=getattr(args, "mix_every", 1),
            compress=args.compress,
            compress_ratio=args.compress_ratio,
            error_feedback=not args.no_error_feedback,
            compress_schedule=args.compress_schedule,
            schedule_threshold=args.schedule_threshold,
            schedule_warmup=args.schedule_warmup,
            schedule_rounds=args.schedule_rounds,
            topology=getattr(args, "topology", "static"),
            drop_p=getattr(args, "drop_p", 0.0),
            radius=getattr(args, "radius", 0.5),
            local_updates=getattr(args, "local_updates", 1),
            gradient_tracking=getattr(args, "gradient_tracking", False),
            ef_rebase_every=getattr(args, "ef_rebase_every", 8),
            ef_rebase_threshold=getattr(args, "ef_rebase_threshold", 0.0),
            straggler_p=getattr(args, "straggler_p", 0.0),
            outage_p=getattr(args, "outage_p", 0.0),
            outage_len=getattr(args, "outage_len", 10),
            straggler_skips_compute=getattr(
                args, "straggler_skips_compute", False),
            seed=args.seed,
            sanitize=getattr(args, "sanitize", False),
        )
        if args.nodes is not None:
            spec["num_nodes"] = args.nodes
        if args.lr is not None:
            spec["lr"] = args.lr
        if args.graph is not None:
            # only rebuild graph_kwargs when the CLI actually changes the
            # graph — re-naming the task's own graph must not clobber its
            # parameters (e.g. the paper's erdos_renyi p) with CLI defaults
            if args.graph != spec.get("graph") or "graph_kwargs" not in spec:
                spec["graph_kwargs"] = (
                    {"p": args.p, "seed": args.seed}
                    if args.graph == "erdos_renyi" else {})
            spec["graph"] = args.graph
        return cls(**spec)
